//! MLP training harness over the PJRT artifacts — the §G.1 workload.
//!
//! [`MlpProblem`] implements [`StochasticProblem`]: a stochastic gradient
//! is the compiled `mlp_step_*` artifact (loss + full parameter gradient,
//! Pallas matmul kernels inside) evaluated on a random minibatch;
//! evaluation runs the same artifact over a fixed deterministic slice of
//! the eval split.  Parameters live as one flat `f64` vector on the server,
//! staged to `f32` at the PJRT boundary — so every scheduler from
//! [`crate::coordinator`] drives neural-network training unchanged.

use crate::anyhow;
use crate::util::error::Result;

use crate::data::partition::Partition;
use crate::data::{Dataset, IMG_PIXELS, N_CLASSES};
use crate::opt::{StochasticProblem, WorkerCtx};
use crate::prng::Prng;
use crate::runtime::PjrtRuntime;

/// Layer layout parsed from the artifact manifest meta.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerLayout {
    pub in_dim: usize,
    pub out_dim: usize,
    pub w_offset: usize,
    pub b_offset: usize,
}

/// Neural-network training problem backed by `mlp_step_*` artifacts.
pub struct MlpProblem {
    runtime: PjrtRuntime,
    step_entry: String,
    eval_entry: String,
    pub dims: Vec<usize>,
    pub layout: Vec<LayerLayout>,
    pub param_count: usize,
    pub batch: usize,
    train: Dataset,
    eval: Dataset,
    /// Per-worker shards of the train split (non-IID training); `None`
    /// means every worker samples the full split.
    shards: Option<Vec<Vec<u32>>>,
    /// Number of deterministic eval batches averaged per evaluation.
    eval_batches: usize,
    init_seed: u64,
    // staging buffers
    pf32: Vec<f32>,
    xb: Vec<f32>,
    yb: Vec<f32>,
}

impl MlpProblem {
    /// Load from a runtime whose manifest carries `mlp_step_{tag}` /
    /// `mlp_eval_{tag}` entries, with the given train/eval data.
    pub fn new(mut runtime: PjrtRuntime, train: Dataset, eval: Dataset) -> Result<Self> {
        let step = runtime
            .manifest()
            .entries
            .iter()
            .find(|e| e.name.starts_with("mlp_step_"))
            .ok_or_else(|| anyhow!("no mlp_step_* artifact (run `make artifacts`)"))?
            .clone();
        let eval_entry = step.name.replace("mlp_step_", "mlp_eval_");
        let meta = &step.meta;
        let dims: Vec<usize> = meta
            .get("dims")
            .as_arr()
            .ok_or_else(|| anyhow!("meta.dims"))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let batch = meta
            .get("batch")
            .as_usize()
            .ok_or_else(|| anyhow!("meta.batch"))?;
        let param_count = meta
            .get("param_count")
            .as_usize()
            .ok_or_else(|| anyhow!("meta.param_count"))?;
        let layout = meta
            .get("layout")
            .as_arr()
            .ok_or_else(|| anyhow!("meta.layout"))?
            .iter()
            .map(|l| LayerLayout {
                in_dim: l.get("in_dim").as_usize().unwrap_or(0),
                out_dim: l.get("out_dim").as_usize().unwrap_or(0),
                w_offset: l.get("w_offset").as_usize().unwrap_or(0),
                b_offset: l.get("b_offset").as_usize().unwrap_or(0),
            })
            .collect::<Vec<_>>();
        assert_eq!(dims[0], IMG_PIXELS, "artifact input dim vs dataset");
        assert_eq!(*dims.last().unwrap(), N_CLASSES);
        runtime.warmup(&step.name)?;
        Ok(Self {
            runtime,
            step_entry: step.name.clone(),
            eval_entry,
            pf32: vec![0.0; param_count],
            xb: vec![0.0; batch * IMG_PIXELS],
            yb: vec![0.0; batch * N_CLASSES],
            dims,
            layout,
            param_count,
            batch,
            train,
            eval,
            shards: None,
            eval_batches: 4,
            init_seed: 0xF17,
        })
    }

    pub fn load_default(train: Dataset, eval: Dataset) -> Result<Self> {
        Self::new(PjrtRuntime::load_default()?, train, eval)
    }

    pub fn set_init_seed(&mut self, seed: u64) {
        self.init_seed = seed;
    }

    pub fn set_eval_batches(&mut self, n: usize) {
        self.eval_batches = n.max(1);
    }

    /// Train under per-worker data shards: worker `w`'s minibatches are
    /// drawn only from `partition.shards[w]` (indices into the train
    /// split). Pass a partition from [`crate::data::partition`].
    pub fn set_shards(&mut self, partition: Partition) {
        assert!(
            partition.is_disjoint_cover(self.train.len()),
            "partition must cover the train split"
        );
        assert!(
            partition.shards.iter().all(|s| !s.is_empty()),
            "every worker needs a non-empty shard"
        );
        self.shards = Some(partition.shards);
    }

    /// One artifact call: `(loss, grad)` on the batch currently staged in
    /// `self.xb/self.yb`.
    fn step_on_staged(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        for (o, &v) in self.pf32.iter_mut().zip(x) {
            *o = v as f32;
        }
        let results = self
            .runtime
            .execute_f32(&self.step_entry, &[&self.pf32, &self.xb, &self.yb])
            .expect("mlp_step execution failed");
        let loss = results[0][0] as f64;
        for (g, &v) in grad.iter_mut().zip(&results[1]) {
            *g = v as f64;
        }
        loss
    }

    /// Classification accuracy on the eval split (via `mlp_eval_*`).
    pub fn accuracy(&mut self, x: &[f64]) -> Result<f64> {
        for (o, &v) in self.pf32.iter_mut().zip(x) {
            *o = v as f32;
        }
        let b = self.batch;
        let n = self.eval.len().min(self.eval_batches * b);
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut start = 0;
        while seen < n {
            self.eval.fill_batch_at(start, b, &mut self.xb, &mut self.yb);
            let logits = &self
                .runtime
                .execute_f32(&self.eval_entry, &[&self.pf32, &self.xb])?[0];
            let take = b.min(n - seen);
            for j in 0..take {
                let row = &logits[j * N_CLASSES..(j + 1) * N_CLASSES];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                let label = self.eval.labels[(start + j) % self.eval.len()] as usize;
                if pred == label {
                    correct += 1;
                }
            }
            seen += take;
            start += b;
        }
        Ok(correct as f64 / seen as f64)
    }
}

impl StochasticProblem for MlpProblem {
    fn dim(&self) -> usize {
        self.param_count
    }

    fn stoch_grad(&mut self, x: &[f64], ctx: WorkerCtx<'_>, grad: &mut [f64]) -> f64 {
        let b = self.batch;
        // disjoint field borrows: dataset + shards read, staging buffers
        // written
        match &self.shards {
            Some(shards) => {
                assert!(
                    ctx.worker < shards.len(),
                    "worker {} has no shard (partition built for {} workers)",
                    ctx.worker,
                    shards.len()
                );
                self.train.sample_batch_from(
                    &shards[ctx.worker],
                    b,
                    ctx.rng,
                    &mut self.xb,
                    &mut self.yb,
                );
            }
            None => self.train.sample_batch(b, ctx.rng, &mut self.xb, &mut self.yb),
        }
        self.step_on_staged(x, grad)
    }

    fn eval_value_grad(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        // deterministic average over fixed eval batches
        let b = self.batch;
        let nb = self.eval_batches;
        let mut loss_sum = 0.0;
        grad.fill(0.0);
        let mut gtmp = vec![0.0; grad.len()];
        for i in 0..nb {
            self.eval.fill_batch_at(i * b, b, &mut self.xb, &mut self.yb);
            loss_sum += self.step_on_staged(x, &mut gtmp);
            for (g, &t) in grad.iter_mut().zip(&gtmp) {
                *g += t;
            }
        }
        let inv = 1.0 / nb as f64;
        for g in grad.iter_mut() {
            *g *= inv;
        }
        loss_sum * inv
    }

    fn init_point(&self) -> Vec<f64> {
        // Glorot-uniform per layer, biases zero — from the manifest layout.
        let mut rng = Prng::seed_from_u64(self.init_seed);
        let mut p = vec![0.0; self.param_count];
        for l in &self.layout {
            let limit = (6.0 / (l.in_dim + l.out_dim) as f64).sqrt();
            for i in 0..(l.in_dim * l.out_dim) {
                p[l.w_offset + i] = rng.f64_in(-limit, limit);
            }
            // biases already zero
        }
        p
    }
}
