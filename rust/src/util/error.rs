//! Minimal `anyhow`-compatible error plumbing.
//!
//! Exists because no `anyhow` is available in the offline build (the same
//! reason `util::json` replaces `serde` and `bench_util` replaces
//! `criterion`). Provides exactly the subset the framework uses: a
//! string-backed [`Error`], [`Result`], the [`crate::anyhow!`],
//! [`crate::bail!`] and [`crate::ensure!`] macros, and the [`Context`]
//! extension trait for annotating fallible calls.

use std::fmt;

/// String-backed error value (this crate's `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error {
            msg: msg.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like `anyhow`, `Error` deliberately does *not* implement
// `std::error::Error`: that is what keeps this blanket conversion (and
// therefore `?` on any std error type) coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on any displayable error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/here")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    fn bails(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            bail!("x too large: {x}");
        }
        Ok(x)
    }

    #[test]
    fn context_prepends_message() {
        let err = io_fail().unwrap_err();
        let s = format!("{err:#}");
        assert!(s.starts_with("reading config:"), "{s}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_format_and_bail() {
        assert_eq!(bails(5).unwrap(), 5);
        assert_eq!(format!("{}", bails(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", bails(101).unwrap_err()), "x too large: 101");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
