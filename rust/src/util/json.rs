//! Minimal JSON: value type, recursive-descent parser, compact writer.
//!
//! Exists because no `serde`/`serde_json` is available in the offline build
//! environment.  Supports the full JSON grammar needed by the AOT artifact
//! manifest (`artifacts/manifest.json`) and by the metrics writers: objects,
//! arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]` convenience; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Index into an array; `Json::Null` when out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-by-byte
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize to compact JSON text.
pub fn write(value: &Json) -> String {
    let mut out = String::new();
    write_into(value, &mut out);
    out
}

fn write_into(value: &Json, out: &mut String) {
    match value {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

/// Builder helpers for writers.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Encode an `f64` that may be non-finite: JSON `Num`s cannot carry
/// NaN/±∞, so those become the strings `"nan"` / `"inf"` / `"-inf"`.
/// This is the one canonical encoding shared by the sweep journal, the
/// provenance sidecar, and the process-substrate setup frames — decode
/// with [`get_fnum`].
pub fn fnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".into())
    } else if v > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

/// Decode an [`fnum`]-encoded value (plain numbers pass through).
pub fn get_fnum(j: &Json) -> Option<f64> {
    match j {
        Json::Num(n) => Some(*n),
        Json::Str(s) => match s.as_str() {
            "nan" => Some(f64::NAN),
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            _ => None,
        },
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").at(0), &Json::Num(1.0));
        assert_eq!(j.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(j.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn escapes_and_unicode() {
        let j = parse(r#""é\t\\ 😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é\t\\ 😀"));
        let j2 = parse("\"héllo\"").unwrap();
        assert_eq!(j2.as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"entries":[{"name":"q","shape":[16,4],"ok":true}],"v":1.5}"#;
        let j = parse(src).unwrap();
        let out = write(&j);
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format_version": 1,
          "entries": [
            {"name": "quad_vg_d64", "file": "quad_vg_d64.hlo.txt",
             "args": [{"shape": [64], "dtype": "float32"}],
             "results": [{"shape": [], "dtype": "float32"},
                          {"shape": [64], "dtype": "float32"}],
             "meta": {"kind": "quadratic", "d": 64, "lo": -0.25}}
          ]
        }"#;
        let j = parse(src).unwrap();
        assert_eq!(j.get("format_version").as_usize(), Some(1));
        let e = j.get("entries").at(0);
        assert_eq!(e.get("name").as_str(), Some("quad_vg_d64"));
        assert_eq!(e.get("args").at(0).get("shape").at(0).as_usize(), Some(64));
        assert_eq!(e.get("meta").get("lo").as_f64(), Some(-0.25));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
