//! Small shared utilities: a minimal JSON value + parser/writer (used for
//! the artifact manifest and metrics output), `anyhow`-style error
//! plumbing, and misc helpers.

pub mod error;
pub mod json;

/// Format seconds compactly for human-readable logs (`1.23s`, `4.5ms`, `2m03s`).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    if s >= 120.0 {
        let m = (s / 60.0).floor() as u64;
        format!("{m}m{:04.1}s", s - 60.0 * m as f64)
    } else if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}µs", s * 1e6)
    }
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(125.0), "2m05.0s");
        assert_eq!(fmt_secs(1.5), "1.500s");
        assert_eq!(fmt_secs(0.0025), "2.500ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500µs");
    }

    #[test]
    fn div_ceil_cases() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 100), 1);
    }
}
