//! End-to-end CLI tests: run the actual `ringmaster` binary the way a user
//! would and check its output contract.

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_ringmaster"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, ok) = run(&["--help"]);
    assert!(ok);
    for sub in ["run", "compare", "complexity", "fig1", "fig2", "fig3", "train", "sweep"] {
        assert!(stdout.contains(sub), "help missing '{sub}'");
    }
}

#[test]
fn no_args_prints_help() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("usage:"));
}

#[test]
fn unknown_subcommand_fails_cleanly() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn bad_option_value_fails_cleanly() {
    let (_, stderr, ok) = run(&["run", "--d", "not-a-number"]);
    assert!(!ok);
    assert!(stderr.contains("--d"));
}

#[test]
fn complexity_prints_theory_table() {
    let (stdout, _, ok) = run(&["complexity", "--n", "64", "--d", "64"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("T_A (eq.4)"));
    assert!(stdout.contains("linear (τ_i=i)"));
    assert!(stdout.contains("R (eq.9)"));
}

#[test]
fn run_subcommand_reports_convergence_and_writes_csv() {
    let csv = std::env::temp_dir().join("ringmaster_cli_run.csv");
    let csv_s = csv.to_str().unwrap();
    let (stdout, stderr, ok) = run(&[
        "run",
        "--scheduler", "ringmaster",
        "--model", "linear",
        "--d", "16",
        "--n", "16",
        "--r", "8",
        "--gamma", "0.05",
        "--max-iters", "30000",
        "--target-gap", "1e-4",
        "--csv-out", csv_s,
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("time-to-target"));
    let body = std::fs::read_to_string(&csv).unwrap();
    assert!(body.starts_with("series,t,value"));
    assert!(body.lines().count() > 10);
    std::fs::remove_file(&csv).ok();
}

#[test]
fn run_all_scheduler_flavors() {
    for sched in ["asgd", "delay-adaptive", "rennala", "naive", "minibatch"] {
        let (stdout, stderr, ok) = run(&[
            "run",
            "--scheduler", sched,
            "--model", "linear",
            "--d", "16",
            "--n", "8",
            "--gamma", "0.05",
            "--max-iters", "4000",
            "--target-gap", "1e-12", // effectively: run the budget out
        ]);
        assert!(ok, "{sched}: {stdout}\n{stderr}");
        assert!(stdout.contains("final:"), "{sched}");
    }
}

#[test]
fn sweep_emits_long_form_csv() {
    let (stdout, stderr, ok) = run(&[
        "sweep",
        "--alpha", "0.1,1.0,inf",
        "--seeds", "0",
        "--n", "4",
        "--n-data", "120",
        "--batch", "4",
        "--max-iters", "150",
        "--schedulers", "ringmaster,rennala",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    let lines: Vec<&str> = stdout.trim_end().lines().collect();
    assert!(lines[0].starts_with("scheduler,alpha,seed,"), "{}", lines[0]);
    // one row per (scheduler, α, seed) grid point: 2 × 3 × 1
    assert_eq!(lines.len(), 1 + 6, "{stdout}");
    for alpha in ["0.1", "1", "inf"] {
        assert!(
            lines.iter().skip(1).any(|l| l.split(',').nth(1) == Some(alpha)),
            "missing α={alpha} rows in:\n{stdout}"
        );
    }
}

#[test]
fn sweep_journal_interrupt_resume_is_byte_identical_and_shards_cover() {
    let dir = std::env::temp_dir().join(format!("ringmaster_cli_sweep_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("sweep.jsonl");
    std::fs::remove_file(&journal).ok();
    let base = [
        "sweep",
        "--alpha", "inf,0.1",
        "--seeds", "0",
        "--n", "4",
        "--n-data", "120",
        "--batch", "4",
        "--max-iters", "120",
        "--schedulers", "ringmaster,rescaled",
    ];

    // ground truth: uninterrupted, journal-free
    let (fresh, _, ok) = run(&base);
    assert!(ok);

    // invocation 1: journaled, budgeted to 2 of the 4 cells → no CSV yet
    let journal_s = journal.to_str().unwrap().to_string();
    let mut with_journal: Vec<&str> = base.to_vec();
    with_journal.extend(["--journal", journal_s.as_str()]);
    let mut interrupted = with_journal.clone();
    interrupted.extend(["--max-cells", "2"]);
    let (out1, err1, ok1) = run(&interrupted);
    assert!(ok1, "{err1}");
    assert!(out1.is_empty(), "partial sweep must not emit CSV: {out1}");
    assert!(err1.contains("2/4 cells complete"), "{err1}");

    // invocation 2: resume from the journal → CSV identical to fresh
    let (out2, err2, ok2) = run(&with_journal);
    assert!(ok2, "{err2}");
    assert_eq!(out2, fresh, "resumed CSV differs from uninterrupted run");

    // rescaled rows made it into the CSV
    assert!(out2.lines().any(|l| l.starts_with("asgd+rescaled,")), "{out2}");

    // shard fan-out: 1/2 ∪ 2/2 rows = full rows (journal-free)
    let mut shard_rows: Vec<String> = Vec::new();
    for sel in ["1/2", "2/2"] {
        let mut sharded = base.to_vec();
        sharded.extend(["--shard", sel]);
        let (out, err, ok) = run(&sharded);
        assert!(ok, "{err}");
        shard_rows.extend(out.trim_end().lines().skip(1).map(String::from));
    }
    let mut expect: Vec<&str> = fresh.trim_end().lines().skip(1).collect();
    let mut got: Vec<&str> = shard_rows.iter().map(String::as_str).collect();
    expect.sort_unstable();
    got.sort_unstable();
    assert_eq!(got, expect, "shard union must equal the full grid");

    // a conflicting grid against the same journal is refused (the
    // duplicated --max-iters wins in the option map, changing the grid)
    let mut conflicting: Vec<&str> = with_journal.clone();
    conflicting.extend(["--max-iters", "121"]);
    let (_, err3, ok3) = run(&conflicting);
    assert!(!ok3, "journal for another grid must be refused");
    assert!(err3.contains("different grid"), "{err3}");

    // --max-cells without --journal would silently discard the compute
    let mut unjournaled = base.to_vec();
    unjournaled.extend(["--max-cells", "2"]);
    let (_, err4, ok4) = run(&unjournaled);
    assert!(!ok4, "budgeted run without a journal must be refused");
    assert!(err4.contains("--journal"), "{err4}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_wallclock_deterministic_matches_sim_in_shared_columns() {
    let base = [
        "sweep",
        "--alpha", "inf,0.1",
        "--seeds", "0",
        "--n", "4",
        "--n-data", "120",
        "--batch", "4",
        "--max-iters", "120",
        "--schedulers", "ringmaster,rennala",
    ];
    let (sim, err_s, ok_s) = run(&base);
    assert!(ok_s, "{err_s}");
    let mut wc_args = base.to_vec();
    wc_args.extend(["--substrate", "wallclock", "--deterministic", "--wc-threads", "2"]);
    let (wc, err_w, ok_w) = run(&wc_args);
    assert!(ok_w, "{err_w}");

    let strip = |out: &str, suffix: &str| -> Vec<String> {
        out.trim_end()
            .lines()
            .skip(1)
            .map(|l| {
                l.strip_suffix(suffix)
                    .unwrap_or_else(|| panic!("row missing {suffix}: {l}"))
                    .to_string()
            })
            .collect()
    };
    assert!(sim
        .lines()
        .next()
        .unwrap()
        .ends_with(",substrate,wall_median,wall_min"));
    assert_eq!(
        strip(&sim, ",sim,,"),
        strip(&wc, ",wallclock-det,,"),
        "deterministic wall-clock sweep must match sim in every shared column"
    );

    // an unknown substrate is a clean CLI error
    let mut bad = base.to_vec();
    bad.extend(["--substrate", "gpu"]);
    let (_, err, ok) = run(&bad);
    assert!(!ok);
    assert!(err.contains("--substrate"), "{err}");
}

#[test]
fn sweep_merge_reassembles_a_cross_machine_fan_out() {
    let dir = std::env::temp_dir().join(format!("ringmaster_cli_merge_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (s1, s2, merged) = (
        dir.join("s1.jsonl"),
        dir.join("s2.jsonl"),
        dir.join("merged.jsonl"),
    );
    for p in [&s1, &s2, &merged] {
        std::fs::remove_file(p).ok();
    }
    let base = [
        "sweep",
        "--alpha", "inf,0.1",
        "--seeds", "0",
        "--n", "4",
        "--n-data", "120",
        "--batch", "4",
        "--max-iters", "120",
        "--schedulers", "ringmaster,rennala",
    ];
    // uninterrupted ground truth
    let (fresh, _, ok) = run(&base);
    assert!(ok);
    // two shards, each journaling to its own file (one per "machine")
    for (sel, journal) in [("1/2", &s1), ("2/2", &s2)] {
        let mut sharded = base.to_vec();
        let j = journal.to_str().unwrap().to_string();
        sharded.extend(["--shard", sel]);
        let owned = ["--journal".to_string(), j];
        let refs: Vec<&str> = sharded
            .iter()
            .copied()
            .chain(owned.iter().map(String::as_str))
            .collect();
        let (_, err, ok) = run(&refs);
        assert!(ok, "{err}");
    }
    // merge the shard journals
    let (_, err, ok) = run(&[
        "sweep",
        "merge",
        "--out",
        merged.to_str().unwrap(),
        s1.to_str().unwrap(),
        s2.to_str().unwrap(),
    ]);
    assert!(ok, "{err}");
    assert!(err.contains("merged 2 journals"), "{err}");

    // the merged journal reproduces the uninterrupted CSV, running nothing
    let mut final_args = base.to_vec();
    final_args.extend(["--journal", merged.to_str().unwrap()]);
    let (out, err, ok) = run(&final_args);
    assert!(ok, "{err}");
    assert!(err.contains("[4 done]"), "merged journal must cover the grid: {err}");
    assert_eq!(out, fresh, "merged-journal CSV differs from uninterrupted run");

    // merge without --out is a clean error
    let (_, err, ok) = run(&["sweep", "merge", s1.to_str().unwrap()]);
    assert!(!ok);
    assert!(err.contains("--out"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn version_flag_prints_version() {
    let (stdout, _, ok) = run(&["--version"]);
    assert!(ok);
    assert!(stdout.trim().starts_with("ringmaster "), "{stdout}");
}

#[test]
fn unknown_flag_is_rejected_with_a_suggestion() {
    let (_, stderr, ok) = run(&["sweep", "--seedz", "0"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag --seedz"), "{stderr}");
    assert!(stderr.contains("--seeds"), "no did-you-mean in: {stderr}");

    // a dotted key is a config override path, not a registry flag
    let (_, stderr, ok) = run(&["complexity", "--cluster.n", "64"]);
    assert!(ok, "{stderr}");
}

#[test]
fn help_documents_observability_and_report_surfaces() {
    let (stdout, _, ok) = run(&["--help"]);
    assert!(ok);
    let needles =
        ["--provenance", "--trace-dir", "--trace-out", "sweep report", "sweep merge", "--journal"];
    for needle in needles {
        assert!(stdout.contains(needle), "help missing '{needle}'");
    }
}

#[test]
fn run_trace_out_streams_bounded_spans() {
    let dir = std::env::temp_dir().join(format!("ringmaster_cli_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("run.spans.jsonl");
    let trace_s = trace.to_str().unwrap();
    let (stdout, stderr, ok) = run(&[
        "run",
        "--scheduler", "ringmaster",
        "--model", "linear",
        "--d", "16",
        "--n", "8",
        "--gamma", "0.05",
        "--max-iters", "2000",
        "--target-gap", "1e-12",
        "--trace-out", trace_s,
        "--trace-spans", "500",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("span(s)"), "{stdout}");
    assert!(stdout.contains("final:"), "tracing must not change the run output: {stdout}");
    let body = std::fs::read_to_string(&trace).unwrap();
    let n = body.lines().count();
    assert!(n > 0 && n <= 500, "cap must bound the file, got {n} lines");
    assert!(body.lines().next().unwrap().contains("\"outcome\""), "{body}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_provenance_and_report_end_to_end() {
    let dir = std::env::temp_dir().join(format!("ringmaster_cli_report_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("sweep.jsonl");
    std::fs::remove_file(&journal).ok();
    let journal_s = journal.to_str().unwrap().to_string();
    let base = [
        "sweep",
        "--alpha", "inf,0.1",
        "--seeds", "0",
        "--n", "4",
        "--n-data", "120",
        "--batch", "4",
        "--max-iters", "120",
        "--schedulers", "ringmaster,rennala,asgd",
    ];

    // ground truth without any observability
    let (fresh, _, ok) = run(&base);
    assert!(ok);

    // --provenance requires a journal
    let mut orphan = base.to_vec();
    orphan.push("--provenance");
    let (_, err, ok) = run(&orphan);
    assert!(!ok);
    assert!(err.contains("--journal"), "{err}");

    // journaled + provenance run: CSV bytes unchanged, sidecar written
    let mut instrumented = base.to_vec();
    instrumented.extend(["--journal", journal_s.as_str(), "--provenance"]);
    let (out, err, ok) = run(&instrumented);
    assert!(ok, "{err}");
    assert_eq!(out, fresh, "--provenance must not change the sweep CSV");
    let sidecar = dir.join("sweep.jsonl.prov");
    assert!(sidecar.exists(), "missing provenance sidecar {}", sidecar.display());

    // the report turns journal + sidecar into the paper-style comparison
    let report_csv = dir.join("report.csv");
    let (md, err, ok) = run(&[
        "sweep",
        "report",
        journal_s.as_str(),
        "--csv-out",
        report_csv.to_str().unwrap(),
    ]);
    assert!(ok, "{err}");
    assert!(md.contains("# Sweep report"), "{md}");
    assert!(md.contains("## Per-scheduler comparison"), "{md}");
    assert!(md.contains("## Provenance"), "{md}");
    assert!(md.contains("ringmaster"), "{md}");
    let csv = std::fs::read_to_string(&report_csv).unwrap();
    assert!(csv.starts_with("scheduler,alpha,substrate,"), "{csv}");
    assert!(csv.lines().any(|l| l.starts_with("rennala")), "{csv}");

    // report without a journal argument is a clean error
    let (_, err, ok) = run(&["sweep", "report"]);
    assert!(!ok);
    assert!(err.contains("sweep report"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exec_demo_runs_real_threads() {
    let (stdout, stderr, ok) = run(&[
        "exec-demo",
        "--n", "4",
        "--d", "16",
        "--max-iters", "200",
        "--time-scale", "1e-4",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("exec ringmaster"));
    assert!(stdout.contains("exec asgd"));
}
