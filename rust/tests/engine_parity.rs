//! Sim / wall-clock parity through the unified engine.
//!
//! The same (scheduler, compute model, seed) configuration is run through
//! both [`GradientSource`] implementations — the discrete-event simulator
//! (`Driver` → `SimSource`) and the real-thread pool (`run_wallclock` →
//! `ThreadSource`) — at two strengths:
//!
//! * **Qualitative** (wall-clock arrival order): both descend, both
//!   respect the scheduler's accounting invariants, and Ringmaster's
//!   Lemma 4.1 delay bound (`δ < R` on every consumed gradient) holds on
//!   both substrates. Bitwise agreement is not expected: thread timing
//!   reorders arrivals.
//! * **Bitwise** (`ExecConfig::deterministic`): deliveries are released
//!   in virtual-time order, and — because timing draws come from the
//!   worker's sequential stream and gradient draws from per-assignment
//!   keyed streams on *both* substrates — the full iterate trajectory,
//!   per-worker shard-hit accounting and recorded curves must be
//!   identical, including under label-skew data sharding.
//!
//! The process substrate ([`ringmaster::engine::ProcSource`]) joins the
//! bitwise tier: deterministic child-process cells must reproduce the
//! simulator trajectory bit for bit through the stdio wire protocol
//! (`three_substrates_*` below).

// the historical `run_wallclock*` entry points are exercised on purpose:
// they are deprecated shims over `exec::run_on` and must keep producing
// exactly what they did before the collapse, until their removal
#![allow(deprecated)]

use ringmaster::coordinator::{Decision, Scheduler, SchedulerKind};
use ringmaster::data::{partition, synthetic_mnist, N_CLASSES};
use ringmaster::driver::{Driver, DriverConfig, RunRecord};
use ringmaster::exec::{run_wallclock, run_wallclock_sharded, ExecConfig};
use ringmaster::opt::{LogisticProblem, Noisy, QuadraticProblem, Sharded};
use ringmaster::sim::ComputeModel;

const D: usize = 8;
const N: usize = 4;
const NOISE: f64 = 1e-3;

/// One representative configuration per `SchedulerKind` variant (all 7).
fn all_seven_kinds() -> Vec<SchedulerKind> {
    vec![
        SchedulerKind::Ringmaster { r: 4, gamma: 0.3, cancel: true },
        SchedulerKind::Asgd { gamma: 0.2 },
        SchedulerKind::DelayAdaptive { gamma: 0.3 },
        SchedulerKind::Rennala { b: 3, gamma: 0.4 },
        SchedulerKind::Buffered { b: 3, gamma: 0.3 },
        SchedulerKind::Naive { m_star: 2, gamma: 0.3 },
        SchedulerKind::Minibatch { m: N, gamma: 0.5 },
    ]
}

fn sim_run(sched: &mut dyn Scheduler, model: &ComputeModel, iters: u64, seed: u64) -> RunRecord {
    let mut driver = Driver::new(
        Noisy::new(QuadraticProblem::paper(D), NOISE),
        model.clone(),
        DriverConfig {
            seed,
            max_iters: iters,
            record_every: 50,
            ..Default::default()
        },
    );
    driver.run(sched)
}

fn wall_run(sched: &mut dyn Scheduler, model: &ComputeModel, iters: u64, seed: u64) -> RunRecord {
    let problem = QuadraticProblem::paper(D);
    run_wallclock(
        &problem,
        model,
        sched,
        &ExecConfig {
            time_scale: 2e-4,
            max_iters: iters,
            noise_sigma: NOISE,
            seed,
            record_every: 50,
            ..Default::default()
        },
    )
}

#[test]
fn all_seven_scheduler_kinds_descend_on_both_substrates() {
    let model = ComputeModel::fixed_linear(N);
    for kind in all_seven_kinds() {
        let mut s1 = kind.build();
        let sim = sim_run(s1.as_mut(), &model, 200, 1);
        let mut s2 = kind.build();
        let wall = wall_run(s2.as_mut(), &model, 200, 1);

        for (name, rec) in [("sim", &sim), ("wall", &wall)] {
            assert!(rec.iters > 0, "{}/{name}: no iterate updates", kind.name());
            let first = rec.gap_curve.v[0];
            assert!(
                rec.final_gap < 0.9 * first,
                "{}/{name}: no descent ({first} -> {})",
                kind.name(),
                rec.final_gap
            );
            assert!(!rec.diverged, "{}/{name} diverged", kind.name());
        }
        // substrate marker: only wall-clock runs carry a duration
        assert!(sim.wall.is_none() && wall.wall.is_some(), "{}", kind.name());
    }
}

type RunFn = fn(&mut dyn Scheduler, &ComputeModel, u64, u64) -> RunRecord;

#[test]
fn accounting_invariants_transfer_across_substrates() {
    let model = ComputeModel::fixed_linear(N);

    // ASGD applies every arrival on both substrates
    for run in [sim_run as RunFn, wall_run] {
        let mut s = SchedulerKind::Asgd { gamma: 0.2 }.build();
        let rec = run(s.as_mut(), &model, 150, 2);
        assert_eq!(rec.discarded, 0, "{}", rec.scheduler);
        assert_eq!(rec.applied, rec.iters, "{}", rec.scheduler);
        assert_eq!(rec.accumulated, 0, "{}", rec.scheduler);
    }

    // Rennala: exactly B zero-delay gradients per round, cross-round
    // arrivals dropped — on both substrates, through the one accumulator
    for run in [sim_run as RunFn, wall_run] {
        let mut s = SchedulerKind::Rennala { b: 3, gamma: 0.4 }.build();
        let rec = run(s.as_mut(), &model, 100, 3);
        assert_eq!(rec.accumulated, 3 * rec.iters, "{}", rec.scheduler);
        assert!(rec.discarded > 0, "{}: in-flight work must go stale", rec.scheduler);
    }

    // Buffered ASGD accepts any staleness: batches fill, nothing is dropped
    for run in [sim_run as RunFn, wall_run] {
        let mut s = SchedulerKind::Buffered { b: 3, gamma: 0.3 }.build();
        let rec = run(s.as_mut(), &model, 100, 4);
        assert_eq!(rec.accumulated, 3 * rec.iters, "{}", rec.scheduler);
        assert_eq!(rec.discarded, 0, "{}", rec.scheduler);
    }
}

/// Wraps a scheduler and records the largest delay whose gradient was
/// actually consumed (stepped or accumulated) — the quantity Lemma 4.1 /
/// Theorem 4.1 bound by `R` for Ringmaster ASGD.
struct DelayProbe<S: Scheduler> {
    inner: S,
    max_used_delay: u64,
}

impl<S: Scheduler> DelayProbe<S> {
    fn new(inner: S) -> Self {
        Self {
            inner,
            max_used_delay: 0,
        }
    }
}

impl<S: Scheduler> Scheduler for DelayProbe<S> {
    fn on_arrival(&mut self, worker: usize, delay: u64) -> Decision {
        let d = self.inner.on_arrival(worker, delay);
        if !matches!(d, Decision::Discard) {
            self.max_used_delay = self.max_used_delay.max(delay);
        }
        d
    }

    fn active_workers(&self) -> Option<&[usize]> {
        self.inner.active_workers()
    }

    fn cancel_threshold(&self, k: u64) -> Option<u64> {
        self.inner.cancel_threshold(k)
    }

    fn reassign_after_arrival(&self) -> bool {
        self.inner.reassign_after_arrival()
    }

    fn name(&self) -> String {
        self.inner.name()
    }
}

#[test]
fn ringmaster_delay_bound_holds_on_both_substrates() {
    // wider cluster than R so stale work genuinely exists
    let n = 6;
    let r = 3u64;
    let model = ComputeModel::fixed_linear(n);
    for cancel in [false, true] {
        for (name, run) in [("sim", sim_run as RunFn), ("wall", wall_run)] {
            let mut probe = DelayProbe::new(
                ringmaster::coordinator::RingmasterScheduler::new(r, 0.2, cancel),
            );
            let rec = run(&mut probe, &model, 200, 5);
            assert!(rec.iters > 0, "{name} cancel={cancel}");
            assert!(
                probe.max_used_delay < r,
                "{name} cancel={cancel}: applied delay {} ≥ R={r}",
                probe.max_used_delay
            );
            if cancel {
                assert!(
                    rec.cluster.cancellations > 0,
                    "{name}: Algorithm 5 must stop stale computations (n={n} > R={r})"
                );
            } else {
                assert!(
                    rec.discarded > 0,
                    "{name}: Algorithm 4 must discard stale arrivals (n={n} > R={r})"
                );
            }
        }
    }
}

/// The acceptance test of the sharding contract: identical iterate
/// trajectory and shard-hit accounting for `SimSource` vs `ThreadSource`
/// under label-skew partitioning, for Ringmaster (with Algorithm 5
/// cancellation) and Rennala (with cross-round discards).
#[test]
fn sharded_runs_are_bitwise_identical_across_substrates() {
    let n = 4;
    let seed = 5;
    let ds = synthetic_mnist(240, 0.15, 3);
    let problem = LogisticProblem::from_dataset(&ds, 0.01);
    let part = partition::label_skew(&ds.labels, N_CLASSES, n, 0.3, 7);
    // continuous durations ⇒ virtual completion times are tie-free, so
    // the conservative release order equals the simulator's event order
    let model = ComputeModel::random_paper(n);
    let batch = 4;

    for kind in [
        SchedulerKind::Ringmaster { r: 3, gamma: 0.02, cancel: true },
        SchedulerKind::Rennala { b: 2, gamma: 0.02 },
    ] {
        let mut driver = Driver::new(
            Sharded::new(problem.clone(), part.clone(), batch),
            model.clone(),
            DriverConfig {
                seed,
                max_iters: 60,
                record_every: 10,
                ..Default::default()
            },
        );
        let mut s1 = kind.build();
        let sim = driver.run(s1.as_mut());

        let mut s2 = kind.build();
        let wall = run_wallclock_sharded(
            &problem,
            &part,
            batch,
            &model,
            s2.as_mut(),
            &ExecConfig {
                time_scale: 1e-4,
                max_iters: 60,
                seed,
                record_every: 10,
                deterministic: true,
                ..Default::default()
            },
        );

        let name = kind.name();
        assert!(sim.iters > 0, "{name}: progress");
        assert_eq!(sim.iters, wall.iters, "{name}: iterate count");
        assert_eq!(sim.x_final, wall.x_final, "{name}: iterate trajectory");
        assert_eq!(sim.worker_hits, wall.worker_hits, "{name}: shard hits");
        assert_eq!(sim.applied, wall.applied, "{name}");
        assert_eq!(sim.accumulated, wall.accumulated, "{name}");
        assert_eq!(sim.discarded, wall.discarded, "{name}");
        assert_eq!(
            sim.cluster.cancellations, wall.cluster.cancellations,
            "{name}: Algorithm 5 parity"
        );
        assert_eq!(sim.cluster.assignments, wall.cluster.assignments, "{name}");
        // recorded curves agree in (virtual) time and value
        assert_eq!(sim.gap_curve.t, wall.gap_curve.t, "{name}: record times");
        assert_eq!(sim.gap_curve.v, wall.gap_curve.v, "{name}: record values");
        // substrate markers survive: wall runs still report a duration
        assert!(sim.wall.is_none() && wall.wall.is_some(), "{name}");
        // hit accounting is internally consistent and someone delivered
        assert_eq!(
            sim.worker_hits.iter().sum::<u64>(),
            sim.applied + sim.accumulated,
            "{name}"
        );
        assert!(
            sim.worker_hits[0] > 0,
            "{name}: the fastest worker must land consumed gradients: {:?}",
            sim.worker_hits
        );
    }
}

/// The determinism contract of the intra-cell compute pool
/// (`linalg::par`): the same sharded cell run serially and at pool width
/// N must produce bit-identical trajectories, on both substrates. Chunk
/// boundaries are a function of vector length only and chunk partials
/// fold in ascending index order, so the pool width is unobservable in
/// the math.
#[test]
fn sharded_trajectories_are_bitwise_identical_across_pool_widths() {
    use ringmaster::linalg::par::ComputePool;
    use std::sync::Arc;

    let n = 4;
    let seed = 5;
    let ds = synthetic_mnist(240, 0.15, 3);
    let problem = LogisticProblem::from_dataset(&ds, 0.01);
    let part = partition::label_skew(&ds.labels, N_CLASSES, n, 0.3, 7);
    let model = ComputeModel::random_paper(n);
    let batch = 4;
    let kind = SchedulerKind::Ringmaster { r: 3, gamma: 0.02, cancel: true };
    let dcfg = DriverConfig {
        seed,
        max_iters: 60,
        record_every: 10,
        ..Default::default()
    };

    // simulator substrate: serial `run` vs `run_pooled` at width 3
    let mut driver = Driver::new(
        Sharded::new(problem.clone(), part.clone(), batch),
        model.clone(),
        dcfg.clone(),
    );
    let mut s1 = kind.build();
    let serial = driver.run(s1.as_mut());
    let pool = ComputePool::new(3);
    let mut s2 = kind.build();
    let pooled = driver.run_pooled(s2.as_mut(), &pool);
    assert!(serial.iters > 0, "progress");
    assert_eq!(serial.iters, pooled.iters, "sim: iterate count");
    assert_eq!(serial.x_final, pooled.x_final, "sim: iterate trajectory");
    assert_eq!(serial.worker_hits, pooled.worker_hits, "sim: shard hits");
    assert_eq!(serial.gap_curve.t, pooled.gap_curve.t, "sim: record times");
    assert_eq!(serial.gap_curve.v, pooled.gap_curve.v, "sim: record values");

    // deterministic wall-clock substrate: no pool vs a width-3 pool
    let wall = |compute: Option<Arc<ComputePool>>| {
        let mut s = kind.build();
        run_wallclock_sharded(
            &problem,
            &part,
            batch,
            &model,
            s.as_mut(),
            &ExecConfig {
                time_scale: 1e-4,
                max_iters: 60,
                seed,
                record_every: 10,
                deterministic: true,
                compute,
                ..Default::default()
            },
        )
    };
    let wc_serial = wall(None);
    let wc_pooled = wall(Some(Arc::new(ComputePool::new(3))));
    assert_eq!(wc_serial.iters, wc_pooled.iters, "wallclock: iterate count");
    assert_eq!(wc_serial.x_final, wc_pooled.x_final, "wallclock: trajectory");
    assert_eq!(wc_serial.worker_hits, wc_pooled.worker_hits, "wallclock: hits");
    assert_eq!(wc_serial.gap_curve.v, wc_pooled.gap_curve.v, "wallclock: curves");
    // and the two substrates still agree with each other under pooling
    assert_eq!(pooled.x_final, wc_pooled.x_final, "cross-substrate parity");
}

/// Deterministic mode is not sharding-specific: the classic §G noisy
/// quadratic also reproduces bit-for-bit across substrates.
#[test]
fn deterministic_noisy_runs_are_bitwise_identical_across_substrates() {
    let model = ComputeModel::random_paper(N);
    let mut d = Driver::new(
        Noisy::new(QuadraticProblem::paper(D), NOISE),
        model.clone(),
        DriverConfig {
            seed: 11,
            max_iters: 80,
            record_every: 20,
            ..Default::default()
        },
    );
    let mut s1 = SchedulerKind::Ringmaster { r: 3, gamma: 0.3, cancel: true }.build();
    let sim = d.run(s1.as_mut());

    let problem = QuadraticProblem::paper(D);
    let mut s2 = SchedulerKind::Ringmaster { r: 3, gamma: 0.3, cancel: true }.build();
    let wall = run_wallclock(
        &problem,
        &model,
        s2.as_mut(),
        &ExecConfig {
            time_scale: 1e-4,
            max_iters: 80,
            noise_sigma: NOISE,
            seed: 11,
            record_every: 20,
            deterministic: true,
            ..Default::default()
        },
    );
    assert!(sim.iters > 0);
    assert_eq!(sim.iters, wall.iters);
    assert_eq!(sim.x_final, wall.x_final);
    assert_eq!(sim.worker_hits, wall.worker_hits);
    assert_eq!(sim.gap_curve.t, wall.gap_curve.t);
    assert_eq!(sim.gap_curve.v, wall.gap_curve.v);
}

/// The hot-path rework's golden-curve contract, end to end: for every
/// `SchedulerKind`, the monomorphized engine loop (`run_pooled_kind` —
/// static dispatch, slab-recycled sim assignments, incremental per-worker
/// RNG streams, lazy side tables) must reproduce the dynamic-dispatch
/// `Driver::run` trajectory bit for bit on the simulator, *and* agree with
/// the deterministic wall-clock substrate (whose worker threads derive the
/// same per-assignment streams independently). Any allocation-recycling or
/// RNG-caching bug that moves a single sampled bit fails here.
#[test]
fn monomorphized_kind_path_matches_dyn_path_on_both_substrates() {
    use ringmaster::engine::{run_pooled_kind, SimSource};
    use ringmaster::linalg::par::ComputePool;

    // continuous durations ⇒ tie-free virtual times, the regime where the
    // deterministic wall-clock release order equals the simulator's
    let model = ComputeModel::random_paper(N);
    let iters = 120u64;
    let seed = 9u64;
    let pool = ComputePool::new(1);

    for kind in all_seven_kinds() {
        // dynamic dispatch through the Driver (the historical path)
        let mut s1 = kind.build();
        let dyn_rec = sim_run(s1.as_mut(), &model, iters, seed);

        // static dispatch straight through the engine
        let mut problem = Noisy::new(QuadraticProblem::paper(D), NOISE);
        let mut source = SimSource::new(model.clone(), seed);
        source.set_track_stale(kind.build().cancel_threshold(u64::MAX).is_some());
        let cfg = DriverConfig {
            seed,
            max_iters: iters,
            record_every: 50,
            ..Default::default()
        };
        let kind_rec = run_pooled_kind(&mut problem, &mut source, &kind, &cfg, &pool);

        let name = kind.name();
        assert!(dyn_rec.iters > 0, "{name}: progress");
        assert_eq!(dyn_rec.iters, kind_rec.iters, "{name}: iterate count");
        assert_eq!(dyn_rec.x_final, kind_rec.x_final, "{name}: trajectory");
        assert_eq!(dyn_rec.worker_hits, kind_rec.worker_hits, "{name}: hits");
        assert_eq!(dyn_rec.gap_curve.t, kind_rec.gap_curve.t, "{name}: record times");
        assert_eq!(dyn_rec.gap_curve.v, kind_rec.gap_curve.v, "{name}: record values");
        assert_eq!(
            (dyn_rec.applied, dyn_rec.accumulated, dyn_rec.discarded),
            (kind_rec.applied, kind_rec.accumulated, kind_rec.discarded),
            "{name}: decision accounting"
        );
        assert_eq!(
            dyn_rec.cluster.cancellations, kind_rec.cluster.cancellations,
            "{name}: Algorithm 5 parity"
        );

        // deterministic wall-clock twin agrees with the static sim path
        let mut s2 = kind.build();
        let wall = run_wallclock(
            &QuadraticProblem::paper(D),
            &model,
            s2.as_mut(),
            &ExecConfig {
                time_scale: 1e-4,
                max_iters: iters,
                noise_sigma: NOISE,
                seed,
                record_every: 50,
                deterministic: true,
                ..Default::default()
            },
        );
        assert_eq!(kind_rec.iters, wall.iters, "{name}: wallclock iterate count");
        assert_eq!(kind_rec.x_final, wall.x_final, "{name}: wallclock trajectory");
        assert_eq!(kind_rec.worker_hits, wall.worker_hits, "{name}: wallclock hits");
        assert_eq!(kind_rec.gap_curve.v, wall.gap_curve.v, "{name}: wallclock curves");
    }
}

/// The PR-10 acceptance test: sim ≡ wallclock-det ≡ proc-det, bit for
/// bit, for every scheduler family — the same configuration run through
/// all three [`ringmaster::engine::SubstrateSpec`] arms of the one
/// [`ringmaster::exec::run_on`] entry point. The process runs cross a
/// real OS pipe per gradient (length-prefixed binary frames, child
/// processes rebuilding the problem from its wire description), so any
/// f64 round-trip loss, frame reorder, or cancellation-generation drift
/// moves a bit and fails here.
#[test]
fn three_substrates_bitwise_identical_for_all_seven_kinds() {
    use ringmaster::engine::{ProcPoolConfig, SubstrateSpec, ThreadPoolConfig, WorkerTask};
    use ringmaster::exec::{noisy_workload, run_on};
    use std::path::PathBuf;
    use std::time::Duration;

    let model = ComputeModel::random_paper(N);
    let iters = 120u64;
    let seed = 9u64;
    let problem = QuadraticProblem::paper(D);
    let dcfg = DriverConfig {
        seed,
        max_iters: iters,
        record_every: 50,
        ..Default::default()
    };
    let task = WorkerTask::Quadratic { d: D, noise_sigma: NOISE };
    let max_wall = Duration::from_secs(60);
    let mut proc_cfg = ProcPoolConfig::virtual_time(seed, max_wall);
    // the test harness is not the worker binary; point at the real CLI
    proc_cfg.worker_bin = Some(PathBuf::from(env!("CARGO_BIN_EXE_ringmaster")));

    for kind in all_seven_kinds() {
        let run = |spec: &SubstrateSpec| {
            let (eval, samplers) = noisy_workload(&problem, NOISE, N);
            let mut s = kind.build();
            run_on(spec, eval, samplers, Some(task.clone()), &model, s.as_mut(), &dcfg)
        };
        let sim = run(&SubstrateSpec::sim());
        let wall = run(&SubstrateSpec::Threads(ThreadPoolConfig::virtual_time(
            seed, NOISE, max_wall,
        )));
        let proc = run(&SubstrateSpec::Process(proc_cfg.clone()));

        let name = kind.name();
        assert!(sim.iters > 0, "{name}: progress");
        for (sub, rec) in [("wallclock-det", &wall), ("process-det", &proc)] {
            assert_eq!(sim.iters, rec.iters, "{name}/{sub}: iterate count");
            assert_eq!(sim.x_final, rec.x_final, "{name}/{sub}: trajectory");
            assert_eq!(sim.worker_hits, rec.worker_hits, "{name}/{sub}: hits");
            assert_eq!(sim.gap_curve.t, rec.gap_curve.t, "{name}/{sub}: record times");
            assert_eq!(sim.gap_curve.v, rec.gap_curve.v, "{name}/{sub}: record values");
            assert_eq!(
                (sim.applied, sim.accumulated, sim.discarded),
                (rec.applied, rec.accumulated, rec.discarded),
                "{name}/{sub}: decision accounting"
            );
            assert_eq!(
                sim.cluster.cancellations, rec.cluster.cancellations,
                "{name}/{sub}: Algorithm 5 parity"
            );
        }
        // substrate markers: the child pool reports its PIDs and a clean
        // (restart-free) run, and only the sim run lacks a wall duration
        assert!(sim.wall.is_none() && proc.wall.is_some(), "{name}");
        let stats = proc.proc.as_ref().expect("process runs carry ProcRunStats");
        assert_eq!(stats.pids.len(), N, "{name}: one child per worker");
        assert!(stats.pids.iter().all(|&p| p != 0), "{name}: live PIDs");
        assert_eq!(stats.total_restarts(), 0, "{name}: no crashes expected");
        assert!(sim.proc.is_none() && wall.proc.is_none(), "{name}");
    }
}

/// Sharded logistic cells over the wire: the child rebuilds dataset,
/// partition and problem from nothing but the `WorkerTask` description,
/// and the deterministic process run must still match the simulator bit
/// for bit — for Ringmaster (Algorithm 5 cancellation crossing the pipe
/// as generation-stamped CANCEL frames) and Rennala (cross-round
/// discards).
#[test]
fn three_substrates_sharded_proc_det_matches_sim() {
    use ringmaster::engine::{ProcPoolConfig, SubstrateSpec, WorkerTask};
    use ringmaster::exec::{run_on, sharded_workload};
    use std::path::PathBuf;
    use std::time::Duration;

    let n = 4;
    let seed = 5u64;
    let n_data = 240;
    let batch = 4;
    let lambda = 0.01;
    let alpha = 0.3;
    // parent-side construction mirrors the child's SETUP-frame rebuild:
    // synthetic_mnist(n_data, 0.15, seed) + alpha_partition(α, seed)
    let ds = synthetic_mnist(n_data, 0.15, seed);
    let problem = LogisticProblem::from_dataset(&ds, lambda);
    let part = partition::alpha_partition(&ds.labels, n, alpha, seed);
    let model = ComputeModel::random_paper(n);
    let dcfg = DriverConfig {
        seed,
        max_iters: 60,
        record_every: 10,
        ..Default::default()
    };
    let task = WorkerTask::ShardedLogistic {
        n_data,
        n_workers: n,
        batch,
        lambda,
        alpha,
        data_seed: seed,
    };
    let mut proc_cfg = ProcPoolConfig::virtual_time(seed, Duration::from_secs(60));
    proc_cfg.worker_bin = Some(PathBuf::from(env!("CARGO_BIN_EXE_ringmaster")));

    for kind in [
        SchedulerKind::Ringmaster { r: 3, gamma: 0.02, cancel: true },
        SchedulerKind::Rennala { b: 2, gamma: 0.02 },
    ] {
        let run = |spec: &SubstrateSpec| {
            let (eval, samplers) = sharded_workload(&problem, &part, batch, n);
            let mut s = kind.build();
            run_on(spec, eval, samplers, Some(task.clone()), &model, s.as_mut(), &dcfg)
        };
        let sim = run(&SubstrateSpec::sim());
        let proc = run(&SubstrateSpec::Process(proc_cfg.clone()));

        let name = kind.name();
        assert!(sim.iters > 0, "{name}: progress");
        assert_eq!(sim.iters, proc.iters, "{name}: iterate count");
        assert_eq!(sim.x_final, proc.x_final, "{name}: iterate trajectory");
        assert_eq!(sim.worker_hits, proc.worker_hits, "{name}: shard hits");
        assert_eq!(sim.applied, proc.applied, "{name}");
        assert_eq!(sim.accumulated, proc.accumulated, "{name}");
        assert_eq!(sim.discarded, proc.discarded, "{name}");
        assert_eq!(
            sim.cluster.cancellations, proc.cluster.cancellations,
            "{name}: Algorithm 5 parity over the wire"
        );
        assert_eq!(sim.gap_curve.t, proc.gap_curve.t, "{name}: record times");
        assert_eq!(sim.gap_curve.v, proc.gap_curve.v, "{name}: record values");
        assert_eq!(
            proc.proc.as_ref().map(|p| p.total_restarts()),
            Some(0),
            "{name}: clean child pool"
        );
    }
}

#[test]
fn noise_free_runs_agree_on_counts_and_neighborhood() {
    // with σ = 0 both substrates apply the same number of exact gradients;
    // arrival order differs (thread timing), but both must land in the
    // same small neighbourhood of the optimum
    let model = ComputeModel::fixed_linear(N);
    let iters = 300u64;

    let mut d = Driver::new(
        Noisy::new(QuadraticProblem::paper(D), 0.0),
        model.clone(),
        DriverConfig {
            seed: 1,
            max_iters: iters,
            record_every: 50,
            ..Default::default()
        },
    );
    let mut s1 = SchedulerKind::Asgd { gamma: 0.2 }.build();
    let sim = d.run(s1.as_mut());

    let problem = QuadraticProblem::paper(D);
    let mut s2 = SchedulerKind::Asgd { gamma: 0.2 }.build();
    let wall = run_wallclock(
        &problem,
        &model,
        s2.as_mut(),
        &ExecConfig {
            time_scale: 2e-4,
            max_iters: iters,
            noise_sigma: 0.0,
            seed: 1,
            ..Default::default()
        },
    );

    assert_eq!(sim.iters, iters);
    assert_eq!(wall.iters, iters);
    assert_eq!(sim.discarded, 0);
    assert_eq!(wall.discarded, 0);
    let f0 = sim.gap_curve.v[0];
    assert!(sim.final_gap < 0.5 * f0);
    assert!(wall.final_gap < 0.5 * f0);
}
