//! Integration tests for the §6-future-work extensions: communication
//! costs, the buffered-async baseline, server-side optimizers, and the
//! execution-trace instrumentation.

use ringmaster::coordinator::SchedulerKind;
use ringmaster::driver::{Driver, DriverConfig, ServerOpt};
use ringmaster::experiments::{run_quadratic, QuadExpConfig};
use ringmaster::metrics::SpanOutcome;
use ringmaster::opt::{Noisy, QuadraticProblem};
use ringmaster::prng::TimeDist;
use ringmaster::sim::{CommModel, ComputeModel, LinkCost};

fn cfg() -> QuadExpConfig {
    QuadExpConfig {
        d: 16,
        n_workers: 32,
        noise_sigma: 0.01,
        seed: 0,
        max_iters: 200_000,
        max_time: f64::INFINITY,
        target_gap: Some(1e-4),
        record_every: 100,
    }
}

#[test]
fn communication_costs_slow_convergence_proportionally() {
    let base = ComputeModel::fixed_linear(32);
    let kind = SchedulerKind::Ringmaster { r: 16, gamma: 0.03, cancel: true };
    let t_free = run_quadratic(&cfg(), base.clone(), &kind)
        .time_to_target()
        .unwrap();
    // links that double every worker's per-gradient latency roughly double
    // time-to-target (τ_i=i, symmetric links of τ_i/2 each way)
    let links: Vec<LinkCost> = (1..=32)
        .map(|i| LinkCost::symmetric(TimeDist::Constant(i as f64 / 2.0)))
        .collect();
    let slow = CommModel::new(base, links).into_compute_model();
    let t_comm = run_quadratic(&cfg(), slow, &kind).time_to_target().unwrap();
    let ratio = t_comm / t_free;
    assert!(
        (1.6..=2.6).contains(&ratio),
        "doubling latency should ~double time: ratio {ratio}"
    );
}

#[test]
fn buffered_async_converges_and_sits_between_extremes() {
    let model = ComputeModel::fixed_linear(32);
    let t_buf = run_quadratic(
        &cfg(),
        model.clone(),
        &SchedulerKind::Buffered { b: 8, gamma: 0.2 },
    )
    .time_to_target();
    assert!(t_buf.is_some(), "buffered-async must converge");
    // sanity: it behaves like a batched method — ~B gradients per update
    let rec = run_quadratic(
        &cfg(),
        model,
        &SchedulerKind::Buffered { b: 8, gamma: 0.2 },
    );
    assert_eq!(rec.accumulated, 8 * rec.iters);
    assert_eq!(rec.discarded, 0, "buffered accepts stale gradients");
}

#[test]
fn momentum_server_optimizer_runs_under_async_scheduling() {
    let run = |opt: ServerOpt, gamma: f64| {
        let problem = Noisy::new(QuadraticProblem::paper(32), 0.001);
        let dcfg = DriverConfig {
            seed: 2,
            max_iters: 30_000,
            record_every: 100,
            server_opt: opt,
            ..Default::default()
        };
        let mut driver = Driver::new(problem, ComputeModel::fixed_linear(8), dcfg);
        let mut sched = SchedulerKind::Ringmaster { r: 8, gamma, cancel: true }.build();
        driver.run(sched.as_mut())
    };
    let sgd = run(ServerOpt::Sgd, 0.2);
    // β is kept moderate: with stale gradients the effective stepsize is
    // γ/(1−β), and stability needs γ·L·R/(1−β) ≲ 1 (β=0.9 at this γ
    // genuinely diverges — a real interaction between momentum and
    // asynchrony, checked below).
    let mom = run(ServerOpt::Momentum { beta: 0.5 }, 0.08);
    assert!(sgd.final_gap.is_finite());
    assert!(!mom.diverged, "moderate-β momentum must be stable");
    assert!(
        mom.final_gap < 1e-4,
        "momentum should reach a small gap, got {:.3e}",
        mom.final_gap
    );
    // and the aggressive configuration really is unstable under staleness —
    // the divergence guard must catch it
    let wild = run(ServerOpt::Momentum { beta: 0.95 }, 0.2);
    assert!(
        wild.diverged || wild.final_gap > 1.0,
        "expected instability at β=0.95, γ=0.2"
    );
}

#[test]
fn trace_accounts_for_every_outcome() {
    let problem = Noisy::new(QuadraticProblem::paper(8), 0.01);
    let dcfg = DriverConfig {
        seed: 1,
        max_iters: 2000,
        record_every: 200,
        record_trace: true,
        ..Default::default()
    };
    let mut driver = Driver::new(problem, ComputeModel::fixed_linear(8), dcfg);
    // Algorithm 5 with a tight threshold: applied + cancelled spans
    let mut sched = SchedulerKind::Ringmaster { r: 2, gamma: 0.1, cancel: true }.build();
    let rec = driver.run(sched.as_mut());
    let trace = rec.trace.as_ref().expect("trace recorded");
    let count = |o: SpanOutcome| trace.spans().filter(|s| s.outcome == o).count() as u64;
    assert_eq!(count(SpanOutcome::Applied), rec.applied.min(trace.len() as u64));
    assert!(count(SpanOutcome::Cancelled) > 0);
    // span sanity: within sim time, nonnegative durations
    for s in trace.spans() {
        assert!(s.end >= s.start);
        assert!(s.end <= rec.sim_time + 1e-9);
    }
    // Algorithm 5 never lets a delivery go stale ⇒ no Discarded spans
    assert_eq!(count(SpanOutcome::Discarded), 0);
    // efficiency is in [0,1] and someone did useful work
    let eff = trace.efficiency(rec.sim_time);
    assert!(eff.iter().all(|&e| (0.0..=1.0).contains(&e)));
    assert!(eff[0] > 0.5, "fastest worker should be mostly useful: {eff:?}");
}

#[test]
fn trace_csv_export() {
    let problem = Noisy::new(QuadraticProblem::paper(4), 0.0);
    let dcfg = DriverConfig {
        seed: 3,
        max_iters: 50,
        record_trace: true,
        ..Default::default()
    };
    let mut driver = Driver::new(problem, ComputeModel::fixed_equal(2, 1.0), dcfg);
    let mut sched = SchedulerKind::Asgd { gamma: 0.1 }.build();
    let rec = driver.run(sched.as_mut());
    let path = std::env::temp_dir().join("ringmaster_ext_trace.csv");
    rec.trace.as_ref().unwrap().write_csv(&path).unwrap();
    let body = std::fs::read_to_string(&path).unwrap();
    assert!(body.lines().count() > 40);
    assert!(body.contains("applied"));
    std::fs::remove_file(path).ok();
}

#[test]
fn heterogeneous_links_dominated_by_uplink_stragglers() {
    // compute is uniform; links make the tail slow — async schedulers must
    // still converge by leaning on the well-connected workers
    let base = ComputeModel::fixed_equal(16, 1.0);
    let links: Vec<LinkCost> = (0..16)
        .map(|i| {
            if i < 12 {
                LinkCost::free()
            } else {
                LinkCost::symmetric(TimeDist::Constant(200.0))
            }
        })
        .collect();
    let model = CommModel::new(base, links).into_compute_model();
    let rec = run_quadratic(
        &cfg(),
        model,
        &SchedulerKind::Ringmaster { r: 12, gamma: 0.04, cancel: true },
    );
    assert!(
        rec.time_to_target().is_some(),
        "must converge despite 4 link-straggler workers (gap {})",
        rec.final_gap
    );
}
