//! Property-level verification of the paper's core lemmas/theorems against
//! the simulator.
//!
//! * **Lemma 4.1**: under the fixed computation model, any `R` consecutive
//!   iterate updates of Algorithm 4/5 finish within `t(R)` (eq. 7).
//! * **Theorem 4.1 invariant**: every *applied* gradient has `δ^k < R`
//!   (`‖x^k − x^{k−δ}‖` windows stay bounded — the residual-estimation
//!   backbone).
//! * **Lemma 5.1 consistency**: the universal model with `v_i = 1/τ_i`
//!   produces the same arrival dynamics as the fixed model.

use ringmaster::complexity;
use ringmaster::coordinator::{RingmasterScheduler, SchedulerKind};
use ringmaster::driver::{Driver, DriverConfig};
use ringmaster::opt::{Noisy, QuadraticProblem};
use ringmaster::sim::ComputeModel;
use ringmaster::testkit;

fn run_with_update_times(
    taus: &[f64],
    r: u64,
    cancel: bool,
    iters: u64,
    seed: u64,
) -> ringmaster::driver::RunRecord {
    let n = taus.len();
    let problem = Noisy::new(QuadraticProblem::paper(16), 0.01);
    let cfg = DriverConfig {
        seed,
        max_iters: iters,
        record_every: 10_000,
        record_update_times: true,
        ..Default::default()
    };
    let mut driver = Driver::new(
        problem,
        ComputeModel::Fixed {
            taus: taus.to_vec(),
        },
        cfg,
    );
    let _ = n;
    let mut sched = RingmasterScheduler::new(r, 0.05, cancel);
    driver.run(&mut sched)
}

#[test]
fn lemma41_window_bound_random_profiles() {
    testkit::check("lemma 4.1 window ≤ t(R)", |g| {
        let n = g.usize_in(2, 24);
        let taus = g.tau_profile(n, 0.2, 30.0);
        let r = g.usize_in(1, 12) as u64;
        let cancel = g.bool();
        let rec = run_with_update_times(&taus, r, cancel, 400, g.rng.next_u64());
        if rec.update_times.len() < r as usize {
            return; // not enough updates to form a window
        }
        let t_r = complexity::t_of_r(&taus, r);
        let worst = rec.max_window_time(r as usize).unwrap();
        assert!(
            worst <= t_r + 1e-9,
            "R={r} cancel={cancel} taus={taus:?}: window {worst} > t(R) {t_r}"
        );
    });
}

#[test]
fn lemma41_bound_is_not_vacuous() {
    // the measured worst window should be within a small constant of t(R)
    // for the linear profile (the bound is tight up to ~2x by its proof)
    let taus: Vec<f64> = (1..=16).map(|i| i as f64).collect();
    let r = 8;
    let rec = run_with_update_times(&taus, r, true, 2000, 7);
    let t_r = complexity::t_of_r(&taus, r);
    let worst = rec.max_window_time(r as usize).unwrap();
    assert!(worst <= t_r);
    assert!(
        worst >= 0.05 * t_r,
        "worst window {worst} suspiciously far below t(R) {t_r} — check the harness"
    );
}

#[test]
fn applied_delays_always_below_r() {
    // Theorem 4.1's structural invariant, via the virtual-delay tracker
    // cross-check: simulate and re-derive every applied delay.
    testkit::check("applied δ < R", |g| {
        let n = g.usize_in(2, 16);
        let taus = g.tau_profile(n, 0.5, 20.0);
        let r = g.usize_in(1, 6) as u64;
        let rec = run_with_update_times(&taus, r, false, 300, g.rng.next_u64());
        // Algorithm 4 discards everything at δ ≥ R: with small R and a wide
        // τ spread there must be discards, and iterate count = applied count.
        assert_eq!(rec.iters, rec.applied);
        if r == 1 && n > 1 {
            assert!(rec.discarded > 0, "R=1 on n>1 must discard");
        }
    });
}

#[test]
fn universal_constant_power_matches_fixed_model() {
    testkit::check("universal ≡ fixed for v=1/τ", |g| {
        let n = g.usize_in(2, 10);
        let taus = g.tau_profile(n, 0.5, 10.0);
        let seed = g.rng.next_u64();
        let run = |model: ComputeModel| {
            let problem = Noisy::new(QuadraticProblem::paper(8), 0.0);
            let cfg = DriverConfig {
                seed,
                max_iters: 200,
                record_every: 50,
                record_update_times: true,
                ..Default::default()
            };
            let mut driver = Driver::new(problem, model, cfg);
            let mut sched = SchedulerKind::Ringmaster {
                r: 4,
                gamma: 0.1,
                cancel: true,
            }
            .build();
            driver.run(sched.as_mut())
        };
        let fixed = run(ComputeModel::Fixed { taus: taus.clone() });
        let uni = run(ComputeModel::universal_from_taus(&taus));
        assert_eq!(fixed.iters, uni.iters);
        for (a, b) in fixed.update_times.iter().zip(&uni.update_times) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(fixed.x_final, uni.x_final);
    });
}

#[test]
fn theorem42_iteration_budget_suffices() {
    // Theorem 4.1/4.2: with γ and R from the theory, K (eq. 10) updates
    // bring the average ‖∇f‖² under ε.  Run the paper pipeline end-to-end
    // on a small instance and check the *recorded* gradnorm got under ε.
    let d = 32;
    let noise = 0.01;
    let problem = QuadraticProblem::paper(d);
    use ringmaster::opt::Problem;
    let eps = 1e-3;
    let c = complexity::Constants::new(
        problem.smoothness().unwrap(),
        problem.delta(),
        d as f64 * noise * noise,
        eps,
    );
    let r = complexity::default_r(c.sigma_sq, c.eps);
    let gamma = complexity::theorem_stepsize(r, c);
    let k = complexity::iteration_complexity(r, c);
    let cfg = DriverConfig {
        seed: 3,
        max_iters: k,
        eps: Some(eps),
        record_every: (k / 400).max(1),
        ..Default::default()
    };
    let mut driver = Driver::new(
        Noisy::new(problem, noise),
        ComputeModel::fixed_linear(16),
        cfg,
    );
    let mut sched = RingmasterScheduler::new(r, gamma, true);
    let rec = driver.run(&mut sched);
    assert!(
        rec.time_to_eps.is_some(),
        "K={k} updates with theory (R={r}, γ={gamma:.2e}) must reach ε={eps}; final ‖∇f‖²={}",
        rec.final_gradnorm_sq
    );
}
