//! Observability contracts of the scenario layer: provenance sidecars and
//! span traces are *pure observers* —
//!
//! * enabling `--provenance` / a trace dir changes neither the sweep CSV
//!   nor the journal semantics (resume + merge still reproduce the
//!   uninterrupted bytes);
//! * the provenance sidecar round-trips every executed cell, survives a
//!   resume, and merges across shard journals;
//! * span traces emitted from the simulator clock and from the
//!   deterministic wall-clock substrate describe the same execution.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use ringmaster::coordinator::SchedulerKind;
use ringmaster::experiments::heterogeneity::HetConfig;
use ringmaster::metrics::SpanWriter;
use ringmaster::scenario::{
    self, merge_journals, merge_provenance, read_sidecar, CellStore, GridOptions, GridSpec,
    ProvenanceStore, ShardSel, Substrate,
};
use ringmaster::util::json;

fn tiny_cfg() -> HetConfig {
    HetConfig {
        n_data: 120,
        n_workers: 4,
        batch: 4,
        lambda: 0.01,
        max_iters: 120,
        record_every: 40,
        alphas: vec![f64::INFINITY, 0.1],
        seeds: vec![0],
        schedulers: vec![
            SchedulerKind::Ringmaster { r: 4, gamma: 0.02, cancel: true }.into(),
            SchedulerKind::Rennala { b: 2, gamma: 0.02 }.into(),
        ],
        substrate: Substrate::Sim,
        eps: None,
    }
}

fn tiny_spec() -> GridSpec {
    tiny_cfg().grid_spec().unwrap()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ringmaster_obs_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn provenance_and_traces_leave_csv_bytes_untouched() {
    let spec = tiny_spec();
    let dir = tmp_dir("neutral");

    // ground truth: plain journal-free run
    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    // fully-instrumented run: journal + provenance + span traces
    let journal = dir.join("sweep.jsonl");
    let spans = dir.join("spans");
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let opts = GridOptions {
        provenance: true,
        trace_dir: Some(spans.clone()),
        trace_spans: 10_000,
        ..GridOptions::default()
    };
    let run =
        scenario::run_grid_configured(&spec, ShardSel::ALL, Some(&mut store), None, &opts).unwrap();
    assert!(run.is_complete());
    drop(store);

    assert_eq!(
        scenario::grid_csv(&run.rows).as_bytes(),
        fresh_csv.as_bytes(),
        "observers must not perturb the sweep CSV"
    );

    // sidecar round-trip: one record per executed cell, sane fields
    let (fp, records) = read_sidecar(&journal).unwrap().expect("sidecar written");
    assert_eq!(fp, spec.fingerprint());
    assert_eq!(records.len(), spec.len());
    let keys: Vec<String> = spec.cells.iter().map(|c| c.key()).collect();
    for rec in &records {
        assert!(keys.contains(&rec.key), "unknown cell key {}", rec.key);
        assert_eq!(rec.attempts, 1);
        assert_eq!(rec.repeats, 1, "sim cells record a single repeat");
        assert!(rec.wall_secs >= 0.0);
        assert!(rec.code.contains("+bin:"), "code fingerprint: {}", rec.code);
        assert!(!rec.host.is_empty() && !rec.os.is_empty() && rec.cores >= 1);
        assert_eq!(rec.substrate, "sim");
    }

    // one span file per cell, every line a parseable span object
    let mut files: Vec<PathBuf> = std::fs::read_dir(&spans)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    files.sort();
    assert_eq!(files.len(), spec.len(), "one trace file per cell");
    for f in &files {
        let body = std::fs::read_to_string(f).unwrap();
        assert!(!body.is_empty(), "{}", f.display());
        for line in body.lines() {
            let j = json::parse(line).unwrap();
            assert!(j.get("worker").as_f64().is_some());
            assert!(j.get("outcome").as_str().is_some());
        }
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn provenance_survives_interrupt_resume_with_identical_output() {
    let spec = tiny_spec();
    let dir = tmp_dir("resume");
    let journal = dir.join("sweep.jsonl");

    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    let opts = GridOptions { provenance: true, ..GridOptions::default() };

    // invocation 1: interrupted after 2 of 4 cells
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let partial =
        scenario::run_grid_configured(&spec, ShardSel::ALL, Some(&mut store), Some(2), &opts)
            .unwrap();
    assert!(!partial.is_complete());
    drop(store);
    let (_, after_interrupt) = read_sidecar(&journal).unwrap().expect("partial sidecar");
    assert_eq!(after_interrupt.len(), 2, "interrupted run journaled 2 provenance records");

    // invocation 2: resume — only the missing cells run (and gain records)
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let resumed =
        scenario::run_grid_configured(&spec, ShardSel::ALL, Some(&mut store), None, &opts).unwrap();
    assert!(resumed.is_complete());
    assert_eq!(resumed.ran, 2);
    drop(store);

    assert_eq!(
        scenario::grid_csv(&resumed.rows).as_bytes(),
        fresh_csv.as_bytes(),
        "resumed provenance-enabled CSV must be byte-identical"
    );
    let (_, records) = read_sidecar(&journal).unwrap().expect("full sidecar");
    assert_eq!(records.len(), spec.len());
    let mut keys: Vec<&str> = records.iter().map(|r| r.key.as_str()).collect();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys.len(), spec.len(), "exactly one record per cell after resume");

    // reopening the sidecar sees the same records (append-only round trip)
    let store = ProvenanceStore::open(&journal, &spec.fingerprint()).unwrap();
    assert_eq!(store.recorded().len(), spec.len());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_sidecars_merge_alongside_their_journals() {
    let spec = tiny_spec();
    let dir = tmp_dir("merge");
    let (s1, s2, merged) = (dir.join("s1.jsonl"), dir.join("s2.jsonl"), dir.join("merged.jsonl"));

    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    let opts = GridOptions { provenance: true, ..GridOptions::default() };
    for (i, journal) in [&s1, &s2].into_iter().enumerate() {
        let sel = ShardSel { index: i, count: 2 };
        let mut store = CellStore::open(journal, &spec.fingerprint(), spec.len()).unwrap();
        let run =
            scenario::run_grid_configured(&spec, sel, Some(&mut store), None, &opts).unwrap();
        assert!(run.is_complete());
    }

    let inputs = vec![s1.clone(), s2.clone()];
    let stats = merge_journals(&inputs, &merged).unwrap();
    assert_eq!(stats.cells, spec.len());
    let n = merge_provenance(&inputs, &merged, &spec.fingerprint()).unwrap();
    assert_eq!(n, spec.len(), "merged sidecar covers every cell");

    // the merged journal + sidecar reproduce the uninterrupted outputs
    let mut store = CellStore::open(&merged, &spec.fingerprint(), spec.len()).unwrap();
    let noop = scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), None).unwrap();
    assert_eq!(noop.ran, 0, "merged journal covers the grid");
    assert_eq!(scenario::grid_csv(&noop.rows).as_bytes(), fresh_csv.as_bytes());
    let (fp, records) = read_sidecar(&merged).unwrap().expect("merged sidecar");
    assert_eq!(fp, spec.fingerprint());
    assert_eq!(records.len(), spec.len());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn span_traces_agree_between_sim_and_deterministic_wallclock() {
    let spec = tiny_spec();
    let dir = tmp_dir("parity");

    // same cell, two substrates; deterministic wall clock is contractually
    // bit-identical to the simulator, so the emitted span streams must
    // describe the same (worker, start_k, outcome) execution
    let cell = spec.cells[0].clone();
    let trace_of = |cell: &ringmaster::scenario::Cell, name: &str| -> Vec<(u64, u64, String)> {
        let path = dir.join(name);
        let writer = SpanWriter::create(&path, 100_000).unwrap();
        let sink = Arc::new(Mutex::new(writer));
        let (rec, _) = scenario::run_cell_traced(cell, &spec.budget, Some(sink.clone()));
        assert!(rec.iters > 0);
        sink.lock().unwrap().finish().unwrap();
        drop(sink);
        std::fs::read_to_string(&path)
            .unwrap()
            .lines()
            .map(|l| {
                let j = json::parse(l).unwrap();
                (
                    j.get("worker").as_f64().unwrap() as u64,
                    j.get("start_k").as_f64().unwrap() as u64,
                    j.get("outcome").as_str().unwrap().to_string(),
                )
            })
            .collect()
    };

    let sim_spans = trace_of(&cell, "sim.spans.jsonl");
    let wc = cell.clone().on(Substrate::Wallclock { deterministic: true, threads: 2 });
    let wc_spans = trace_of(&wc, "wc.spans.jsonl");

    assert!(!sim_spans.is_empty());
    assert_eq!(sim_spans, wc_spans, "span streams diverge between substrates");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn span_cap_bounds_the_trace_without_perturbing_the_run() {
    let spec = tiny_spec();
    let dir = tmp_dir("cap");
    let cell = spec.cells[0].clone();

    // untraced reference
    let (plain, _) = scenario::run_cell_traced(&cell, &spec.budget, None);

    // hard-capped sink: exactly one line lands on disk, run unchanged
    let path = dir.join("capped.spans.jsonl");
    let sink = Arc::new(Mutex::new(SpanWriter::create(&path, 1).unwrap()));
    let (capped, _) = scenario::run_cell_traced(&cell, &spec.budget, Some(sink.clone()));
    {
        let mut w = sink.lock().unwrap();
        w.finish().unwrap();
        assert_eq!(w.written(), 1);
        assert!(w.dropped() > 0, "the tiny run still out-emits a cap of 1");
    }
    drop(sink);

    assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 1);
    assert_eq!(plain.iters, capped.iters);
    assert_eq!(plain.final_gap, capped.final_gap);
    assert_eq!(plain.x_final, capped.x_final);

    std::fs::remove_dir_all(&dir).ok();
}
