//! PJRT runtime integration: load the real AOT artifacts, execute them,
//! and verify against the native implementations.
//!
//! These tests need two things the default offline build doesn't have:
//! `make artifacts` output, and the real PJRT backend (`--features pjrt`
//! with a vendored `xla` crate). When either is missing they *skip* with a
//! note instead of failing, so the tier-1 suite stays green on a fresh
//! checkout.

use ringmaster::data::synthetic_mnist;
use ringmaster::linalg::nrm2;
use ringmaster::opt::{PjrtQuadratic, Problem, QuadraticProblem};
use ringmaster::prng::Prng;
use ringmaster::runtime::{Manifest, PjrtRuntime};
use ringmaster::train::MlpProblem;

fn have_artifacts() -> bool {
    cfg!(feature = "pjrt") && Manifest::default_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping PJRT round-trip: needs `make artifacts` output and a \
                 `--features pjrt` build (offline default is the stub backend)"
            );
            return;
        }
    };
}

#[test]
fn manifest_has_expected_entries() {
    require_artifacts!();
    let m = Manifest::load(&Manifest::default_dir()).unwrap();
    let names: Vec<&str> = m.entries.iter().map(|e| e.name.as_str()).collect();
    assert!(names.iter().any(|n| n.starts_with("quad_vg_d")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("mlp_step_")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("mlp_eval_")), "{names:?}");
}

#[test]
fn pjrt_quadratic_matches_native_gradient() {
    require_artifacts!();
    let d = 64;
    let pjrt = PjrtQuadratic::load_default(d).expect("load artifact");
    let native = QuadraticProblem::paper(d);
    let mut rng = Prng::seed_from_u64(5);
    for trial in 0..10 {
        let x: Vec<f64> = (0..d).map(|_| rng.normal(0.0, 1.0)).collect();
        let mut g_p = vec![0.0; d];
        let mut g_n = vec![0.0; d];
        let v_p = pjrt.value_grad(&x, &mut g_p);
        let v_n = native.value_grad(&x, &mut g_n);
        assert!(
            (v_p - v_n).abs() < 1e-4 * (1.0 + v_n.abs()),
            "trial {trial}: value {v_p} vs {v_n}"
        );
        let diff: Vec<f64> = g_p.iter().zip(&g_n).map(|(a, b)| a - b).collect();
        assert!(
            nrm2(&diff) < 1e-4 * (1.0 + nrm2(&g_n)),
            "trial {trial}: grad mismatch {}",
            nrm2(&diff)
        );
    }
    assert_eq!(pjrt.f_star(), native.f_star());
}

#[test]
fn pjrt_quadratic_paper_dimension_loads() {
    require_artifacts!();
    let d = 1729;
    let pjrt = PjrtQuadratic::load_default(d).expect("paper-scale artifact");
    let x = vec![0.1; d];
    let mut g = vec![0.0; d];
    let v = pjrt.value_grad(&x, &mut g);
    assert!(v.is_finite());
    assert!(g.iter().all(|gi| gi.is_finite()));
}

#[test]
fn runtime_rejects_bad_inputs() {
    require_artifacts!();
    let mut rt = PjrtRuntime::load_default().unwrap();
    // wrong arity
    assert!(rt.execute_f32("quad_vg_d64", &[]).is_err());
    // wrong size
    let wrong = vec![0.0f32; 3];
    assert!(rt.execute_f32("quad_vg_d64", &[&wrong]).is_err());
    // unknown entry
    assert!(rt.execute_f32("nope", &[]).is_err());
}

#[test]
fn mlp_gradient_is_a_descent_direction_and_loss_decreases() {
    require_artifacts!();
    let ds = synthetic_mnist(400, 0.15, 11);
    let (train, eval) = ds.split(0.25, 11);
    let mut p = MlpProblem::load_default(train, eval).unwrap();
    use ringmaster::opt::StochasticProblem;
    let x0 = p.init_point();
    let mut g = vec![0.0; p.dim()];
    let l0 = p.eval_value_grad(&x0, &mut g);
    assert!(l0.is_finite() && l0 > 0.0);
    // step along −g must reduce the (deterministic) eval loss
    let mut x1 = x0.clone();
    ringmaster::linalg::axpy(-0.1, &g, &mut x1);
    let mut g1 = vec![0.0; p.dim()];
    let l1 = p.eval_value_grad(&x1, &mut g1);
    assert!(l1 < l0, "eval loss must drop: {l0} -> {l1}");
}

#[test]
fn mlp_sgd_improves_accuracy_over_init() {
    require_artifacts!();
    use ringmaster::opt::StochasticProblem;
    let ds = synthetic_mnist(600, 0.15, 13);
    let (train, eval) = ds.split(0.25, 13);
    let mut p = MlpProblem::load_default(train, eval).unwrap();
    let mut x = p.init_point();
    let acc0 = p.accuracy(&x).unwrap();
    let mut rng = Prng::seed_from_u64(1);
    let mut g = vec![0.0; p.dim()];
    for _ in 0..60 {
        p.stoch_grad(
            &x.clone(),
            ringmaster::opt::WorkerCtx { worker: 0, rng: &mut rng },
            &mut g,
        );
        ringmaster::linalg::axpy(-0.2, &g, &mut x);
    }
    let acc1 = p.accuracy(&x).unwrap();
    assert!(
        acc1 > acc0 + 0.2 || acc1 > 0.9,
        "accuracy should improve a lot: {acc0:.2} -> {acc1:.2}"
    );
}
