//! Grid-level acceptance of the process substrate: deterministic
//! child-process cells are content-equal to their sim twins, and child
//! crashes are survivable at two escalation levels.
//!
//! * **CSV parity** — a `Substrate::Process { deterministic: true }` grid
//!   produces rows that differ from the sim grid's only in the trailing
//!   `substrate` column (the PR's acceptance criterion).
//! * **In-run recovery** — a child killed mid-assignment is respawned
//!   within the run (replayed timing draws, reissued assignment); the
//!   CSV stays byte-identical, the grid spends no retry, and the crash is
//!   visible only in the provenance sidecar's `worker_restarts`.
//! * **Escalation** — with the in-run restart budget at zero, the same
//!   crash becomes a transient cell failure: the scenario retry policy
//!   reruns the cell (attempts = 2 journaled) and the CSV is *still*
//!   byte-identical, because every run is seed-derived.

use std::path::PathBuf;

use ringmaster::coordinator::SchedulerKind;
use ringmaster::engine::{ProcFault, WORKER_BIN_ENV};
use ringmaster::experiments::heterogeneity::HetConfig;
use ringmaster::scenario::{
    self, read_sidecar, CellStore, GridOptions, GridSpec, ShardSel, Substrate,
};

/// The test harness binary is not the worker binary — point the spawn
/// path at the real CLI (`ringmaster worker`).
fn point_at_worker_bin() {
    std::env::set_var(WORKER_BIN_ENV, env!("CARGO_BIN_EXE_ringmaster"));
}

const N_WORKERS: usize = 4;

fn tiny_cfg(substrate: Substrate) -> HetConfig {
    HetConfig {
        n_data: 120,
        n_workers: N_WORKERS,
        batch: 4,
        lambda: 0.01,
        max_iters: 120,
        record_every: 40,
        alphas: vec![f64::INFINITY, 0.1],
        seeds: vec![0],
        schedulers: vec![
            SchedulerKind::Ringmaster { r: 4, gamma: 0.02, cancel: true }.into(),
            SchedulerKind::Rennala { b: 2, gamma: 0.02 }.into(),
        ],
        substrate,
        eps: None,
    }
}

fn proc_substrate() -> Substrate {
    // cap concurrent cells at 2: each cell spawns N_WORKERS children
    Substrate::Process { deterministic: true, workers: 2 }
}

fn proc_spec() -> GridSpec {
    tiny_cfg(proc_substrate()).grid_spec().unwrap()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ringmaster_proc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn strip_rows(csv: &str, suffix: &str) -> Vec<String> {
    csv.trim_end()
        .lines()
        .skip(1)
        .map(|l| {
            l.strip_suffix(suffix)
                .unwrap_or_else(|| panic!("row missing {suffix}: {l}"))
                .to_string()
        })
        .collect()
}

#[test]
fn deterministic_process_grid_matches_sim_grid_except_substrate_column() {
    point_at_worker_bin();
    let sim_csv = {
        let spec = tiny_cfg(Substrate::Sim).grid_spec().unwrap();
        let run = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
        scenario::grid_csv(&run.rows)
    };
    let proc_csv = {
        let run = scenario::run_grid(&proc_spec(), ShardSel::ALL, None, None).unwrap();
        scenario::grid_csv(&run.rows)
    };
    assert_eq!(
        strip_rows(&sim_csv, ",sim,,"),
        strip_rows(&proc_csv, ",process-det,,"),
        "every shared CSV column must be substrate-invariant across the wire"
    );
}

#[test]
fn child_crash_is_absorbed_in_run_and_journaled_in_provenance() {
    point_at_worker_bin();
    let spec = proc_spec();
    assert_eq!(spec.len(), 4);

    // ground truth: a crash-free process-substrate sweep
    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    // kill worker 1's child right after its second assignment, in
    // whichever cell reaches that point first (the shared fired flag
    // guarantees exactly one kill across the whole sweep); the default
    // in-run restart budget absorbs it
    let journal = tmp("absorbed.jsonl");
    std::fs::remove_file(&journal).ok();
    std::fs::remove_file(format!("{}.prov", journal.display())).ok();
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let fault = ProcFault::kill_after(1, 2);
    let gopts = GridOptions {
        provenance: true,
        proc_fault: Some(fault.clone()),
        ..Default::default()
    };
    let run =
        scenario::run_grid_configured(&spec, ShardSel::ALL, Some(&mut store), None, &gopts)
            .unwrap();
    assert!(run.is_complete());
    assert!(fault.fired(), "the injected crash must actually happen");
    assert_eq!(run.retries, 0, "an absorbed crash must not spend a grid retry");
    for cell in &spec.cells {
        assert_eq!(store.attempts(&cell.key()), 1, "{}", cell.key());
    }
    drop(store);

    // the CSV cannot tell the crashed sweep from the clean one ...
    let csv = scenario::grid_csv(&run.rows);
    assert_eq!(csv.as_bytes(), fresh_csv.as_bytes());

    // ... but the provenance sidecar can: every cell reports its child
    // PIDs, and exactly one absorbed restart is on record
    let (_, records) = read_sidecar(&journal).unwrap().expect("sidecar written");
    assert_eq!(records.len(), spec.len());
    for rec in &records {
        assert_eq!(rec.substrate, "process-det", "{}", rec.key);
        assert_eq!(rec.worker_pids.len(), N_WORKERS, "{}", rec.key);
        assert!(rec.worker_pids.iter().all(|&p| p != 0), "{}", rec.key);
        assert_eq!(rec.worker_restarts.len(), N_WORKERS, "{}", rec.key);
    }
    let total_restarts: u32 = records
        .iter()
        .map(|r| r.worker_restarts.iter().sum::<u32>())
        .sum();
    assert_eq!(total_restarts, 1, "one kill ⇒ one respawn, in one cell");
}

#[test]
fn exhausted_restart_budget_escalates_to_grid_retry_with_attempts_journaled() {
    point_at_worker_bin();
    let spec = proc_spec();
    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    // same crash, but no in-run respawns allowed: the cell dies with the
    // transient marker and the scenario retry policy reruns it; the fault
    // has already fired, so attempt 2 runs clean
    let journal = tmp("escalated.jsonl");
    std::fs::remove_file(&journal).ok();
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let fault = ProcFault::kill_after(1, 2);
    let gopts = GridOptions {
        proc_restart_budget: 0,
        proc_fault: Some(fault.clone()),
        ..Default::default()
    };
    let run =
        scenario::run_grid_configured(&spec, ShardSel::ALL, Some(&mut store), None, &gopts)
            .unwrap();
    assert!(run.is_complete());
    assert!(fault.fired());
    assert_eq!(run.retries, 1, "the crash must cost exactly one grid retry");
    let attempts: Vec<u32> = spec.cells.iter().map(|c| store.attempts(&c.key())).collect();
    assert_eq!(
        attempts.iter().filter(|&&a| a == 2).count(),
        1,
        "exactly one cell burned a retry: {attempts:?}"
    );
    assert_eq!(
        attempts.iter().filter(|&&a| a == 1).count(),
        spec.len() - 1,
        "{attempts:?}"
    );
    drop(store);

    // seed-derived reruns: the recovered sweep's CSV is byte-identical
    let csv = scenario::grid_csv(&run.rows);
    assert_eq!(csv.as_bytes(), fresh_csv.as_bytes());
}
