//! The timing-wheel [`EventQueue`] against a `BinaryHeap` reference model.
//!
//! The queue's contract is a *total* pop order — `(time, seq)` under
//! `f64::total_cmp` with FIFO tie-breaking — independent of internals.
//! These tests drive the wheel and a straight binary-heap model through
//! identical interleaved push/pop schedules (with heavy exact-timestamp
//! ties, the case where heap internals would otherwise be observable) and
//! demand bitwise-identical behaviour, then smoke the million-worker
//! regime the wheel exists for: a 1M-worker [`Cluster`] must construct
//! and drain 100k events comfortably inside the test timeout.

use std::cmp::Ordering;
use std::sync::Arc;

use ringmaster::sim::{Cluster, ComputeModel, EventQueue, OrdF64};
use ringmaster::testkit;

/// Reference model: the pre-timing-wheel implementation — a `BinaryHeap`
/// over `(time, seq)`-reversed entries.
struct HeapQueue<T> {
    heap: std::collections::BinaryHeap<Entry<T>>,
    seq: u64,
}

struct Entry<T> {
    t: OrdF64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.t, other.seq).cmp(&(self.t, self.seq))
    }
}

impl<T> HeapQueue<T> {
    fn new() -> Self {
        Self {
            heap: std::collections::BinaryHeap::new(),
            seq: 0,
        }
    }
    fn push(&mut self, t: f64, payload: T) {
        self.heap.push(Entry {
            t: OrdF64(t),
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }
    fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.t.0, e.payload))
    }
    fn len(&self) -> usize {
        self.heap.len()
    }
}

fn assert_same_pop(got: Option<(f64, u32)>, want: Option<(f64, u32)>) {
    // Compare times by bit pattern: the contract is total_cmp order, and
    // -0.0 / 0.0 must round-trip exactly through the wheel's key map.
    assert_eq!(
        got.map(|(t, p)| (t.to_bits(), p)),
        want.map(|(t, p)| (t.to_bits(), p))
    );
}

#[test]
fn wheel_matches_heap_reference_with_heavy_ties() {
    testkit::check("wheel == heap, tie-heavy interleavings", |g| {
        let mut wheel = EventQueue::new();
        let mut heap = HeapQueue::new();
        // A tiny alphabet of timestamps makes exact ties the common case;
        // negative, signed-zero and subnormal values cross every branch of
        // the order-preserving key map.
        let mut times = vec![-1.5, -0.0, 0.0, 5e-324, 1.0, 1.0, 2.5];
        for _ in 0..g.usize_in(0, 4) {
            times.push(g.f64_in(-10.0, 1e6));
        }
        let ops = g.usize_in(20, 600);
        let mut id = 0u32;
        for _ in 0..ops {
            // Bias toward pushes so the queues grow and ties accumulate.
            if g.usize_in(0, 2) > 0 || wheel.is_empty() {
                let t = *g.pick(&times);
                wheel.push(t, id);
                heap.push(t, id);
                id += 1;
            } else {
                assert_same_pop(wheel.pop(), heap.pop());
            }
            assert_eq!(wheel.len(), heap.len());
        }
        while heap.len() > 0 {
            assert_same_pop(wheel.pop(), heap.pop());
        }
        assert!(wheel.is_empty());
        assert_same_pop(wheel.pop(), None);
    });
}

#[test]
fn wheel_matches_heap_under_monotone_sim_workload() {
    // The simulator's actual access pattern: times never scheduled into
    // the past, pop-then-reschedule churn at a moving "now".
    testkit::check("wheel == heap, monotone reschedule churn", |g| {
        let mut wheel = EventQueue::new();
        let mut heap = HeapQueue::new();
        let n = g.usize_in(1, 32);
        let mut id = 0u32;
        for _ in 0..n {
            let t = g.f64_in(0.0, 1.0);
            wheel.push(t, id);
            heap.push(t, id);
            id += 1;
        }
        for _ in 0..200 {
            let got = wheel.pop();
            let want = heap.pop();
            assert_same_pop(got, want);
            let Some((now, _)) = got else { break };
            // Reschedule at now + dt (dt >= 0 — exact ties included).
            let dt = if g.bool() { 0.0 } else { g.f64_in(0.0, 3.0) };
            wheel.push(now + dt, id);
            heap.push(now + dt, id);
            id += 1;
        }
    });
}

#[test]
fn million_worker_cluster_constructs_and_drains() {
    // ROADMAP item 4's scale target: the regime where Ringmaster's
    // separation from plain ASGD shows. Construction is O(n), assignment
    // O(1) per worker, and draining 100k arrivals must not degrade —
    // previously each pop paid O(log n) heap sift-downs.
    const N: usize = 1_000_000;
    const DRAIN: usize = 100_000;
    let mut cluster = Cluster::new(ComputeModel::fixed_linear(N), N, 42);
    cluster.set_track_stale(true);
    let x = Arc::new(vec![0.0f64; 8]);
    for w in 0..N {
        cluster.assign(w, 0, &x);
    }
    assert_eq!(cluster.stats.assignments, N as u64);
    let mut last_t = 0.0;
    let mut k = 0u64;
    for _ in 0..DRAIN {
        let a = cluster.next_arrival().expect("queue drained early");
        assert!(a.time >= last_t, "time went backwards");
        last_t = a.time;
        k += 1;
        cluster.assign(a.worker, k, &x);
    }
    assert_eq!(cluster.stats.arrivals, DRAIN as u64);
    // One full-width threshold cancellation: every still-busy worker is
    // stopped and reassigned (a single amortized-O(n) sweep), and the
    // now-stale completion events must be skipped lazily, not searched.
    cluster.cancel_stale(k, k + 1, &x);
    assert!(cluster.stats.cancellations > 0);
    let a = cluster.next_arrival().expect("reassigned workers must finish");
    assert!(a.time >= last_t);
    assert_eq!(a.start_k, k + 1);
    // All snapshots share the one allocation (lazy gradients): the Arc is
    // held once per in-flight assignment plus the caller's handle.
    assert!(Arc::strong_count(&x) <= N + 1);

    // The incremental per-worker draw streams hold at full scale: the
    // cached-base derivation (`assignment_rng`) must be bit-identical to
    // re-keying the (seed, worker, ordinal) triple from scratch — the
    // contract that let the hot path drop one SplitMix64 pass per
    // delivery without moving a single sampled bit.
    use ringmaster::prng::Prng;
    for w in [0usize, 1, 4_242, N / 2, N - 1, a.worker] {
        let ordinal = cluster.assign_ordinal(w);
        let mut inc = cluster.assignment_rng(w);
        let mut rekeyed = Prng::assignment_stream(cluster.data_seed(), w as u64, ordinal);
        for draw in 0..8 {
            assert_eq!(
                inc.next_u64(),
                rekeyed.next_u64(),
                "worker {w} ordinal {ordinal} draw {draw}: incremental stream diverged"
            );
        }
    }
}
