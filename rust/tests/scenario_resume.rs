//! Resume and shard semantics of the scenario orchestration layer — the
//! production contract of checkpointed sweeps:
//!
//! * a sweep interrupted after k cells and resumed produces a CSV
//!   byte-identical to an uninterrupted run's;
//! * the `--shard i/n` slices are pairwise disjoint and their union is
//!   the full grid, with shard CSV rows matching the unsharded rows.

use std::path::PathBuf;

use ringmaster::coordinator::SchedulerKind;
use ringmaster::experiments::heterogeneity::HetConfig;
use ringmaster::scenario::{self, CellStore, GridSpec, ShardSel, Substrate};

fn tiny_spec() -> GridSpec {
    HetConfig {
        n_data: 120,
        n_workers: 4,
        batch: 4,
        lambda: 0.01,
        max_iters: 120,
        record_every: 40,
        alphas: vec![f64::INFINITY, 0.1],
        seeds: vec![0, 1],
        schedulers: vec![
            SchedulerKind::Ringmaster { r: 4, gamma: 0.02, cancel: true }.into(),
            SchedulerKind::Rennala { b: 2, gamma: 0.02 }.into(),
        ],
        substrate: Substrate::Sim,
        eps: None,
    }
    .grid_spec()
    .unwrap()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ringmaster_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn interrupted_then_resumed_sweep_is_byte_identical_to_uninterrupted() {
    let spec = tiny_spec();
    assert_eq!(spec.len(), 8); // 2 sched × 2 α × 2 seeds

    // ground truth: one uninterrupted, journal-free run
    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    assert!(fresh.is_complete());
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    // invocation 1: journaled, interrupted after 3 cells
    let journal = tmp("interrupt.jsonl");
    std::fs::remove_file(&journal).ok();
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let partial = scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), Some(3)).unwrap();
    assert!(!partial.is_complete());
    assert_eq!(partial.ran, 3);
    assert_eq!(partial.remaining, 5);
    drop(store);

    // invocation 2 (a brand-new process would do exactly this): reopen the
    // journal, diff, and run only what is missing
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    assert_eq!(store.completed().len(), 3, "journal kept the finished cells");
    let resumed = scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), None).unwrap();
    assert!(resumed.is_complete());
    assert_eq!(resumed.ran, 5, "only the missing cells reran");

    let resumed_csv = scenario::grid_csv(&resumed.rows);
    assert_eq!(
        resumed_csv.as_bytes(),
        fresh_csv.as_bytes(),
        "resumed CSV must be byte-identical to an uninterrupted run"
    );

    // idempotence: a third invocation runs nothing and still yields the
    // identical CSV, entirely from the journal
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let noop = scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), None).unwrap();
    assert_eq!(noop.ran, 0);
    assert_eq!(scenario::grid_csv(&noop.rows).as_bytes(), fresh_csv.as_bytes());
}

#[test]
fn journal_refuses_a_different_grid() {
    let spec = tiny_spec();
    let journal = tmp("mismatch.jsonl");
    std::fs::remove_file(&journal).ok();
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), Some(1)).unwrap();
    drop(store);

    // same journal, different budget ⇒ different fingerprint ⇒ refused
    let mut other = tiny_spec();
    other.budget.max_iters = 121;
    assert_ne!(other.fingerprint(), spec.fingerprint());
    assert!(CellStore::open(&journal, &other.fingerprint(), other.len()).is_err());
}

#[test]
fn shards_partition_the_grid_and_union_to_the_unsharded_rows() {
    let spec = tiny_spec();
    let full = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let full_csv = scenario::grid_csv(&full.rows);
    let full_rows: Vec<&str> = full_csv.trim_end().lines().skip(1).collect();

    for n in [2usize, 3] {
        // disjoint cover of the cell keys
        let mut seen = std::collections::BTreeSet::new();
        let mut shard_rows: Vec<String> = Vec::new();
        for i in 0..n {
            let sel = ShardSel { index: i, count: n };
            for cell in spec.shard_cells(sel) {
                assert!(seen.insert(cell.key()), "cell on two shards: {}", cell.key());
            }
            // each shard runs (journal-free here) and emits its own rows
            let piece = scenario::run_grid(&spec, sel, None, None).unwrap();
            assert!(piece.is_complete());
            let csv = scenario::grid_csv(&piece.rows);
            shard_rows.extend(csv.trim_end().lines().skip(1).map(String::from));
        }
        assert_eq!(seen.len(), spec.len(), "union covers the grid (n={n})");

        // concatenated shard rows = unsharded rows (as a multiset)
        let mut a: Vec<&str> = shard_rows.iter().map(String::as_str).collect();
        let mut b = full_rows.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "sharded rows differ from unsharded (n={n})");
    }
}

#[test]
fn sweep_csv_has_fairness_columns_for_sharded_cells() {
    let spec = tiny_spec();
    let run = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let csv = scenario::grid_csv(&run.rows);
    let lines: Vec<&str> = csv.trim_end().lines().collect();
    let header: Vec<&str> = lines[0].split(',').collect();
    let min_i = header.iter().position(|&h| h == "shard_loss_min").unwrap();
    let max_i = header.iter().position(|&h| h == "shard_loss_max").unwrap();
    let spread_i = header.iter().position(|&h| h == "shard_loss_spread").unwrap();
    for l in &lines[1..] {
        let f: Vec<&str> = l.split(',').collect();
        let lo: f64 = f[min_i].parse().unwrap();
        let hi: f64 = f[max_i].parse().unwrap();
        let spread: f64 = f[spread_i].parse().unwrap();
        assert!(lo.is_finite() && hi >= lo, "{l}");
        // all three are independently rounded to 7 significant digits
        assert!((spread - (hi - lo)).abs() < 1e-5 * hi.abs().max(1.0), "{l}");
    }
}
