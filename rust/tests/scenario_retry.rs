//! Retry-policy, journal-merge, and substrate-parity semantics of the
//! scenario layer — the production contract of cross-machine sweeps:
//!
//! * a cell that fails transiently is retried, the attempt count lands in
//!   the journal, and the final CSV is byte-identical to a never-failing
//!   run (every run is seed-derived, so attempt 2 computes exactly what
//!   attempt 1 would have);
//! * permanent (content) panics are *not* retried — they propagate on the
//!   first attempt;
//! * `merge_journals` over disjoint shard journals reproduces an
//!   uninterrupted run's CSV byte for byte, and refuses conflicting
//!   payloads under the same cell key;
//! * a deterministic wall-clock grid matches its sim twin in every CSV
//!   column except the trailing substrate tag — on a *sharded* problem,
//!   the regime the paper's wall-clock optimality claim is about.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};

use ringmaster::coordinator::SchedulerKind;
use ringmaster::experiments::heterogeneity::HetConfig;
use ringmaster::scenario::{
    self, merge_journals, CellStore, GridSpec, RetryPolicy, ShardSel, Substrate,
};

fn tiny_cfg() -> HetConfig {
    HetConfig {
        n_data: 120,
        n_workers: 4,
        batch: 4,
        lambda: 0.01,
        max_iters: 120,
        record_every: 40,
        alphas: vec![f64::INFINITY, 0.1],
        seeds: vec![0],
        schedulers: vec![
            SchedulerKind::Ringmaster { r: 4, gamma: 0.02, cancel: true }.into(),
            SchedulerKind::Rennala { b: 2, gamma: 0.02 }.into(),
        ],
        substrate: Substrate::Sim,
        eps: None,
    }
}

fn tiny_spec() -> GridSpec {
    tiny_cfg().grid_spec().unwrap()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ringmaster_retry_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn transient_failure_retries_and_csv_is_byte_identical_to_clean_run() {
    let spec = tiny_spec();
    assert_eq!(spec.len(), 4); // 2 sched × 2 α × 1 seed

    // ground truth: a run where nothing ever fails
    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    assert_eq!(fresh.retries, 0);
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    // inject: the third cell dies once with a transient error, then heals
    let victim = spec.cells[2].key();
    let victim_calls = AtomicU32::new(0);
    let journal = tmp("transient.jsonl");
    std::fs::remove_file(&journal).ok();
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    let run = scenario::run_grid_with(
        &spec,
        ShardSel::ALL,
        Some(&mut store),
        None,
        RetryPolicy::default(),
        1,
        |cell, budget| {
            if cell.key() == victim && victim_calls.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("{}: failure injected for test", RetryPolicy::TRANSIENT_MARKER);
            }
            scenario::run_cell(cell, budget)
        },
    )
    .unwrap();
    assert!(run.is_complete());
    assert_eq!(run.retries, 1, "exactly one extra attempt was spent");
    assert_eq!(victim_calls.load(Ordering::SeqCst), 2, "failed once, succeeded on retry");

    // the journal records the attempt count — audit trail for flaky hosts
    assert_eq!(store.attempts(&victim), 2);
    for cell in &spec.cells {
        if cell.key() != victim {
            assert_eq!(store.attempts(&cell.key()), 1, "{}", cell.key());
        }
    }
    drop(store);

    // ... and the CSV cannot tell the retried run from the clean one
    let csv = scenario::grid_csv(&run.rows);
    assert_eq!(csv.as_bytes(), fresh_csv.as_bytes());

    // resuming from the retried journal is also byte-identical (attempts
    // are bookkeeping, not content)
    let mut store = CellStore::open(&journal, &spec.fingerprint(), spec.len()).unwrap();
    assert_eq!(store.attempts(&victim), 2, "attempts survive reload");
    let resumed = scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), None).unwrap();
    assert_eq!(resumed.ran, 0);
    assert_eq!(scenario::grid_csv(&resumed.rows).as_bytes(), fresh_csv.as_bytes());
}

#[test]
fn transient_classification_is_narrow() {
    let boxed = |s: String| -> Box<dyn std::any::Any + Send> { Box::new(s) };
    assert!(RetryPolicy::is_transient(
        boxed(format!("{}: injected", RetryPolicy::TRANSIENT_MARKER)).as_ref()
    ));
    assert!(RetryPolicy::is_transient(
        boxed("failed to spawn thread: Resource temporarily unavailable".into()).as_ref()
    ));
    // a content panic that merely *mentions* the word is not swallowed
    assert!(!RetryPolicy::is_transient(
        boxed("non-transient divergence in worker 3".into()).as_ref()
    ));
    assert!(!RetryPolicy::is_transient(
        boxed("assertion failed: cell content bug".into()).as_ref()
    ));
}

#[test]
fn permanent_panics_are_not_retried() {
    let spec = tiny_spec();
    let victim = spec.cells[0].key();
    let victim_calls = AtomicU32::new(0);
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        scenario::run_grid_with(
            &spec,
            ShardSel::ALL,
            None,
            None,
            RetryPolicy::new(5),
            1,
            |cell, budget| {
                if cell.key() == victim {
                    victim_calls.fetch_add(1, Ordering::SeqCst);
                    panic!("assertion failed: cell content bug");
                }
                scenario::run_cell(cell, budget)
            },
        )
    }));
    assert!(caught.is_err(), "content panic must propagate");
    assert_eq!(
        victim_calls.load(Ordering::SeqCst),
        1,
        "a non-transient panic must not be retried"
    );
}

#[test]
fn merged_shard_journals_reproduce_an_uninterrupted_run_byte_for_byte() {
    let spec = tiny_spec();
    let fresh = scenario::run_grid(&spec, ShardSel::ALL, None, None).unwrap();
    let fresh_csv = scenario::grid_csv(&fresh.rows);

    // two "machines", each running its disjoint shard into its own journal
    let (s1, s2, merged) = (tmp("shard1.jsonl"), tmp("shard2.jsonl"), tmp("merged.jsonl"));
    for p in [&s1, &s2, &merged] {
        std::fs::remove_file(p).ok();
    }
    for (i, path) in [(0usize, &s1), (1usize, &s2)] {
        let mut store = CellStore::open(path, &spec.fingerprint(), spec.len()).unwrap();
        let piece = scenario::run_grid(
            &spec,
            ShardSel { index: i, count: 2 },
            Some(&mut store),
            None,
        )
        .unwrap();
        assert!(piece.is_complete());
    }

    let stats = merge_journals(&[s1.clone(), s2.clone()], &merged).unwrap();
    assert_eq!(stats.inputs, 2);
    assert_eq!(stats.cells, spec.len());
    assert_eq!(stats.duplicates, 0, "shards are disjoint");

    // the merged journal drives a full-grid invocation that runs nothing
    let mut store = CellStore::open(&merged, &spec.fingerprint(), spec.len()).unwrap();
    assert_eq!(store.completed().len(), spec.len());
    let run = scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), None).unwrap();
    assert_eq!(run.ran, 0, "every cell must come from the merged journal");
    assert_eq!(scenario::grid_csv(&run.rows).as_bytes(), fresh_csv.as_bytes());
}

#[test]
fn merge_refuses_conflicting_payloads_under_the_same_key() {
    let spec = tiny_spec();
    let (a, b, out) = (tmp("conflict_a.jsonl"), tmp("conflict_b.jsonl"), tmp("conflict_m.jsonl"));
    for p in [&a, &b, &out] {
        std::fs::remove_file(p).ok();
    }
    let mut store = CellStore::open(&a, &spec.fingerprint(), spec.len()).unwrap();
    scenario::run_grid(&spec, ShardSel::ALL, Some(&mut store), Some(1)).unwrap();
    drop(store);

    // journal B records the same cell with tampered content
    let mut store = CellStore::open(&b, &spec.fingerprint(), spec.len()).unwrap();
    scenario::run_grid_with(
        &spec,
        ShardSel::ALL,
        Some(&mut store),
        Some(1),
        RetryPolicy::none(),
        1,
        |cell, budget| {
            let (mut rec, conc) = scenario::run_cell(cell, budget);
            rec.iters += 1; // different result, same key
            (rec, conc)
        },
    )
    .unwrap();
    drop(store);

    let err = merge_journals(&[a, b], &out).unwrap_err();
    assert!(format!("{err}").contains("merge conflict"), "{err}");
}

#[test]
fn deterministic_wallclock_grid_matches_sim_grid_on_a_sharded_problem() {
    let sim_csv = {
        let run = scenario::run_grid(&tiny_spec(), ShardSel::ALL, None, None).unwrap();
        scenario::grid_csv(&run.rows)
    };
    let wc_csv = {
        let mut cfg = tiny_cfg();
        cfg.substrate = Substrate::Wallclock { deterministic: true, threads: 2 };
        let run = scenario::run_grid(&cfg.grid_spec().unwrap(), ShardSel::ALL, None, None).unwrap();
        scenario::grid_csv(&run.rows)
    };
    let strip = |csv: &str, suffix: &str| -> Vec<String> {
        csv.trim_end()
            .lines()
            .skip(1)
            .map(|l| {
                l.strip_suffix(suffix)
                    .unwrap_or_else(|| panic!("row missing {suffix}: {l}"))
                    .to_string()
            })
            .collect()
    };
    assert_eq!(
        strip(&sim_csv, ",sim,,"),
        strip(&wc_csv, ",wallclock-det,,"),
        "every shared CSV column must be substrate-invariant"
    );
}
