//! Cross-module integration: the paper's qualitative claims at test scale.
//!
//! * Ringmaster beats classic ASGD in time-to-target on heterogeneous
//!   clusters (the headline).
//! * Ringmaster is competitive with Rennala (both optimal; paper Fig. 2
//!   has Ringmaster winning).
//! * Naive Optimal ASGD matches Ringmaster under the *fixed* model it was
//!   designed for, but collapses under the §2.2 speed flip.
//! * Synchronous minibatch pays the straggler tax.
//!
//! (Sim vs wall-clock parity through the unified engine lives in
//! `tests/engine_parity.rs`.)
//!
//! Test-scale parameters are chosen so the ill-conditioned §G quadratic
//! (κ ~ d²) converges within the budget: d = 16 (κ ≈ 115), per-coordinate
//! noise 0.01 (stochastic gap floor ≈ γ·d·s²/4 ≈ 1e-5), target gap 1e-4.

use ringmaster::complexity;
use ringmaster::coordinator::SchedulerKind;
use ringmaster::driver::{Driver, DriverConfig};
use ringmaster::experiments::{run_quadratic, QuadExpConfig};
use ringmaster::opt::{Noisy, QuadraticProblem};
use ringmaster::sim::{ComputeModel, PowerFn};

const D: usize = 16;
const N: usize = 64;
const R: u64 = 16;
const GAMMA_RING: f64 = 0.03; // ≈ 1/(2RL)
const GAMMA_ASGD: f64 = 1.0 / 128.0; // ≈ 1/(2nL), the classical analyses' choice

fn base_cfg() -> QuadExpConfig {
    QuadExpConfig {
        d: D,
        n_workers: N,
        noise_sigma: 0.01,
        seed: 0,
        max_iters: 400_000,
        max_time: f64::INFINITY,
        target_gap: Some(1e-4),
        record_every: 100,
    }
}

#[test]
fn ringmaster_beats_asgd_on_heterogeneous_cluster() {
    let cfg = base_cfg();
    let model = ComputeModel::fixed_linear(N);
    let t_ring = run_quadratic(
        &cfg,
        model.clone(),
        &SchedulerKind::Ringmaster { r: R, gamma: GAMMA_RING, cancel: true },
    )
    .time_to_target()
    .expect("ringmaster must converge");
    let t_asgd = run_quadratic(&cfg, model, &SchedulerKind::Asgd { gamma: GAMMA_ASGD })
        .time_to_target()
        .unwrap_or(f64::INFINITY);
    assert!(
        t_asgd / t_ring > 1.5,
        "expected ≥1.5x speedup over classic ASGD, got ring={t_ring} asgd={t_asgd}"
    );
}

#[test]
fn ringmaster_competitive_with_rennala() {
    let cfg = base_cfg();
    let model = ComputeModel::fixed_linear(N);
    let t_ring = run_quadratic(
        &cfg,
        model.clone(),
        &SchedulerKind::Ringmaster { r: R, gamma: GAMMA_RING, cancel: true },
    )
    .time_to_target()
    .unwrap();
    // Rennala applies the batch average, so its tuned stepsize is ≈ B×larger
    let t_renn = run_quadratic(
        &cfg,
        model,
        &SchedulerKind::Rennala { b: R, gamma: 0.4 },
    )
    .time_to_target()
    .unwrap_or(f64::INFINITY);
    assert!(
        t_ring <= 2.0 * t_renn,
        "both optimal — ringmaster {t_ring} vs rennala {t_renn}"
    );
}

#[test]
fn naive_matches_ringmaster_on_fixed_model() {
    let cfg = base_cfg();
    let c = cfg.constants(1e-4);
    let taus: Vec<f64> = (1..=N).map(|i| i as f64).collect();
    let m_star = complexity::naive_m_star(&taus, c.sigma_sq, c.eps);
    let model = ComputeModel::Fixed { taus };
    // Theorem 2.1: naive is optimal when speeds are static
    let gamma_naive = (1.0 / (2.0 * m_star as f64)).min(0.1);
    let t_naive = run_quadratic(&cfg, model.clone(), &SchedulerKind::Naive { m_star, gamma: gamma_naive })
        .time_to_target()
        .expect("naive converges on the model it was designed for");
    let t_ring = run_quadratic(
        &cfg,
        model,
        &SchedulerKind::Ringmaster { r: R, gamma: GAMMA_RING, cancel: true },
    )
    .time_to_target()
    .unwrap();
    assert!(
        t_naive < 3.0 * t_ring && t_ring < 3.0 * t_naive,
        "both near-optimal on fixed model: naive {t_naive} vs ringmaster {t_ring}"
    );
}

#[test]
fn naive_collapses_under_speed_flip() {
    // §2.2: half the cluster is fast before t_flip, the other half after.
    let n = 16;
    let d = 32;
    let t_flip = 300.0;
    let budget = 3000.0;
    let powers: Vec<PowerFn> = (0..n)
        .map(|i| {
            if i < n / 2 {
                PowerFn::Flip { rate_before: 1.0, rate_after: 0.01, t_flip }
            } else {
                PowerFn::Flip { rate_before: 0.01, rate_after: 1.0, t_flip }
            }
        })
        .collect();
    let taus_init: Vec<f64> = (0..n).map(|i| if i < n / 2 { 1.0 } else { 100.0 }).collect();
    let sigma_sq = d as f64 * 1e-4;
    let m_flip = complexity::naive_m_star(&taus_init, sigma_sq, 1e-4);
    assert!(m_flip <= n / 2, "naive should commit to the initially-fast half");

    let run_flip = |kind: SchedulerKind| {
        let problem = Noisy::new(QuadraticProblem::paper(d), 0.01);
        let dcfg = DriverConfig {
            seed: 0,
            max_time: budget,
            max_iters: 10_000_000,
            record_every: 100,
            ..Default::default()
        };
        let mut driver = Driver::new(
            problem,
            ComputeModel::Universal { powers: powers.clone() },
            dcfg,
        );
        let mut sched = kind.build();
        driver.run(sched.as_mut())
    };
    let ring = run_flip(SchedulerKind::Ringmaster { r: 8, gamma: 0.06, cancel: true });
    let naive = run_flip(SchedulerKind::Naive { m_star: m_flip, gamma: 0.06 });
    assert!(
        ring.final_gap < 0.5 * naive.final_gap,
        "flip should cripple naive: ringmaster gap {:.3e} vs naive {:.3e}",
        ring.final_gap,
        naive.final_gap
    );
    assert!(
        ring.iters > naive.iters,
        "ringmaster keeps updating on the newly-fast half: {} vs {}",
        ring.iters,
        naive.iters
    );
}

#[test]
fn minibatch_slower_than_async_on_stragglers() {
    let cfg = base_cfg();
    // one catastrophic straggler: τ_n = 1000 s
    let mut taus: Vec<f64> = (1..=N).map(|i| i as f64).collect();
    *taus.last_mut().unwrap() = 1000.0;
    let model = ComputeModel::Fixed { taus };
    let t_ring = run_quadratic(
        &cfg,
        model.clone(),
        &SchedulerKind::Ringmaster { r: R, gamma: GAMMA_RING, cancel: true },
    )
    .time_to_target()
    .unwrap();
    let t_mb = run_quadratic(
        &cfg,
        model,
        &SchedulerKind::Minibatch { m: N, gamma: 1.0 },
    )
    .time_to_target()
    .unwrap_or(f64::INFINITY);
    assert!(
        t_mb > 3.0 * t_ring,
        "sync minibatch must pay the straggler: {t_mb} vs {t_ring}"
    );
}

