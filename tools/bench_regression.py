#!/usr/bin/env python3
"""Perf-trajectory gate for the `bench-smoke` CI job.

Usage:
  bench_regression.py <fresh.json> <baseline-dir>   # gate (exit 1 on regression)
  bench_regression.py trend <baseline-dir>          # print PR-over-PR trajectories

Gate mode validates the freshly measured BENCH report against the schema
and fails (exit 1) when its throughput regresses more than
REGRESSION_FACTOR against any *comparable, measured* committed baseline
(`BENCH_*.json` in <baseline-dir>). Baselines are comparable when bench,
scale, substrate and n_workers all match; baselines with provenance
"placeholder" (schema committed before a measured value exists) or null
metrics are skipped.

Two throughput surfaces are gated, both higher-is-better at the same
threshold:

* the aggregate `cells_per_sec`, and
* every named metric in the optional `"metrics"` object (events/sec,
  updates/sec, GB/s — written by `benches/hotpath.rs`) that appears in
  **both** the fresh report and the baseline. Metrics only one side
  carries are reported but not gated, so adding a new metric never fails
  the gate against older baselines.

Gate mode also refuses *stale placeholders*: a committed baseline with
provenance "placeholder" whose (bench, scale, substrate, n_workers)
configuration already has a measured committed baseline is dead weight —
it silently exempts its slot from the gate while looking covered. The
gate fails and names the placeholder so it gets replaced (commit a
measured CI artifact over it) or deleted.

Trend mode never fails: it sorts the committed `BENCH_<pr>.json` reports
by PR number, groups them by (bench, scale, substrate, n_workers), and
prints each named metric's trajectory across PRs — the human-readable
perf history that the gate's pairwise ratios can't show.
"""

import glob
import json
import os
import sys

REGRESSION_FACTOR = 1.5

REQUIRED_KEYS = {
    "schema_version",
    "bench",
    "scale",
    "substrate",
    "n_workers",
    "cells",
    "wall_seconds",
    "cells_per_sec",
    "schedulers",
    "provenance",
}


def load(path):
    with open(path) as f:
        return json.load(f)


def check_schema(report, path):
    missing = REQUIRED_KEYS - set(report)
    if missing:
        sys.exit(f"{path}: missing schema keys: {sorted(missing)}")
    if report["schema_version"] != 1:
        sys.exit(f"{path}: unknown schema_version {report['schema_version']}")
    metrics = report.get("metrics")
    if metrics is not None:
        if not isinstance(metrics, dict):
            sys.exit(f"{path}: 'metrics' must be an object of named numbers")
        bad = [k for k, v in metrics.items() if not is_number(v)]
        if bad:
            sys.exit(f"{path}: non-numeric metrics: {sorted(bad)}")


def is_number(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def gate_ratio(name, base_value, fresh_value, failures, path):
    """Higher-is-better gate: fail when baseline/fresh > REGRESSION_FACTOR."""
    if fresh_value <= 0.0:
        sys.exit(f"{path}: fresh {name} is non-positive ({fresh_value})")
    ratio = base_value / fresh_value
    verdict = "REGRESSION" if ratio > REGRESSION_FACTOR else "ok"
    print(
        f"vs {path} [{name}]: baseline {base_value:.3f} "
        f"(baseline/fresh = {ratio:.2f}x) ... {verdict}"
    )
    if ratio > REGRESSION_FACTOR:
        failures.append(f"{path}:{name}")


def pr_number(path):
    """BENCH_<pr>.json → <pr> as int (for chronological sorting)."""
    stem = os.path.basename(path)
    digits = "".join(c for c in stem if c.isdigit())
    return int(digits) if digits else -1


def trend(baseline_dir):
    """Print PR-over-PR metric trajectories. Informational only: exit 0."""
    paths = sorted(
        glob.glob(os.path.join(baseline_dir, "BENCH_*.json")), key=pr_number
    )
    if not paths:
        print(f"no BENCH_*.json reports in {baseline_dir}")
        return
    groups = {}  # (bench, scale, substrate, n_workers) -> [(pr, report)]
    for path in paths:
        report = load(path)
        check_schema(report, path)
        key = (
            report["bench"],
            report["scale"],
            report["substrate"],
            report["n_workers"],
        )
        groups.setdefault(key, []).append((pr_number(path), report))
    for (bench, scale, substrate, n_workers), runs in sorted(groups.items()):
        print(f"== {bench}/{scale}/{substrate} n={n_workers} ==")
        names = ["cells_per_sec"]
        for _, report in runs:
            for name in report.get("metrics") or {}:
                if name not in names:
                    names.append(name)
        for name in names:
            points = []
            for pr, report in runs:
                if report["provenance"] != "measured":
                    points.append(f"PR{pr}: placeholder")
                    continue
                value = (
                    report["cells_per_sec"]
                    if name == "cells_per_sec"
                    else (report.get("metrics") or {}).get(name)
                )
                if is_number(value):
                    points.append(f"PR{pr}: {value:.3f}")
            if points:
                print(f"  {name}: " + "  ".join(points))


def config_key(report):
    return (
        report["bench"],
        report["scale"],
        report["substrate"],
        report["n_workers"],
    )


def check_stale_placeholders(baseline_dir):
    """Fail when a placeholder baseline shares its configuration with a
    measured one: the placeholder was the schema stand-in for exactly that
    measurement and must be replaced (or deleted) once it exists."""
    measured = {}  # config -> path
    placeholders = []  # (path, config)
    for path in sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json"))):
        report = load(path)
        check_schema(report, path)
        key = config_key(report)
        if report["provenance"] == "measured" and is_number(report["cells_per_sec"]):
            measured[key] = path
        else:
            placeholders.append((path, key))
    stale = [
        f"{path} (measured twin: {measured[key]})"
        for path, key in placeholders
        if key in measured
    ]
    if stale:
        sys.exit(
            "stale placeholder baseline(s) — a measured report exists for the "
            "same (bench, scale, substrate, n_workers); commit the measured "
            "artifact over the placeholder or delete it:\n  "
            + "\n  ".join(stale)
        )


def main():
    if len(sys.argv) == 3 and sys.argv[1] == "trend":
        trend(sys.argv[2])
        return
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    fresh_path, baseline_dir = sys.argv[1], sys.argv[2]
    fresh = load(fresh_path)
    check_schema(fresh, fresh_path)
    if fresh["provenance"] != "measured" or not is_number(fresh["cells_per_sec"]):
        sys.exit(f"{fresh_path}: fresh report must be a measured run")
    print(
        f"fresh: {fresh['bench']}/{fresh['scale']}/{fresh['substrate']} "
        f"n={fresh['n_workers']}: {fresh['cells']} cells, "
        f"{fresh['cells_per_sec']:.3f} cells/sec"
    )
    fresh_metrics = fresh.get("metrics") or {}
    for name in sorted(fresh_metrics):
        print(f"fresh metric {name}: {fresh_metrics[name]:.3f}")

    check_stale_placeholders(baseline_dir)
    failures = []
    compared = 0
    for path in sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json"))):
        if os.path.abspath(path) == os.path.abspath(fresh_path):
            continue
        base = load(path)
        check_schema(base, path)
        comparable = all(
            base[k] == fresh[k] for k in ("bench", "scale", "substrate", "n_workers")
        )
        if not comparable:
            print(f"skip {path}: different configuration")
            continue
        if base["provenance"] != "measured" or not is_number(base["cells_per_sec"]):
            print(f"skip {path}: placeholder / unmeasured baseline")
            continue
        compared += 1
        gate_ratio("cells_per_sec", base["cells_per_sec"], fresh["cells_per_sec"], failures, path)
        base_metrics = base.get("metrics") or {}
        for name in sorted(base_metrics):
            if name not in fresh_metrics:
                print(f"note {path}: baseline metric {name} absent from fresh report")
                continue
            gate_ratio(name, base_metrics[name], fresh_metrics[name], failures, path)
        for name in sorted(set(fresh_metrics) - set(base_metrics)):
            print(f"note {path}: new metric {name} has no baseline yet")

    if failures:
        sys.exit(
            f"throughput regressed >{REGRESSION_FACTOR}x against: {failures}"
        )
    print(f"bench gate passed ({compared} comparable baseline(s))")


if __name__ == "__main__":
    main()
